//! High-level execution of block programs on full matrices.
//!
//! Bridges the gap between logical matrices and the blocked representation:
//! splits each program input into its `[rows, cols]` grid of blocks,
//! executes the lowered Loop IR under the two-tier memory simulator, and
//! reassembles block-matrix outputs. Also hosts the tensor-level reference
//! implementations used to cross-check every example program.
//!
//! Three interchangeable backends execute the Loop IR ([`ExecBackend`]):
//!
//! * [`ExecBackend::Interp`] — the tree-walking interpreter
//!   (`loopir::interp`), the semantic ground truth;
//! * [`ExecBackend::Compiled`] — `loopir::compile` flattens the program to
//!   an instruction tape that [`engine`] executes. Outputs and traffic
//!   counters are bit-identical to the interpreter; wall-clock is several
//!   times faster, which is what makes autotune trials and large benches
//!   tractable.
//! * [`ExecBackend::Specialized`] — the same tape, post-processed by
//!   `loopir::compile::specialize_skeleton`: recognized instruction
//!   regions collapse into `Instr::Fused` sites executed by the
//!   pre-monomorphized loop bodies in [`kernels`], removing
//!   per-instruction dispatch from matched nests. Still bit-identical —
//!   outputs and counters.
//!
//! The compiled path stacks four mechanisms (PR 2–3):
//!
//! * **SIMD kernels** — the block operators bottom out in
//!   [`crate::tensor::simd`]'s explicit-width kernels (AVX2 with a
//!   bit-identical scalar fallback; `simd` cargo feature, runtime
//!   `--no-simd` kill-switch);
//! * **batched elementwise VM** — `ComputeKind::Ew` sites evaluate
//!   whole vectors/blocks through [`crate::ir::exprvm`]'s slice-at-a-
//!   time expression VM instead of a per-element stack machine (also
//!   governed by the SIMD kill-switch, and bit-identical either way);
//! * **work-stealing scheduler on a persistent pool** — parallel grid
//!   loops (top-level *or* nested under a serial loop, per
//!   [`crate::loopir::compile`]'s per-loop annotations) are
//!   over-decomposed into chunks and drained through [`sched`]'s
//!   stealing deques across the lazily-spawned, parked workers of
//!   [`pool`] (`Workload::threads` / `--threads` caps the worker
//!   count; threads=1 never touches the pool);
//! * **tape caching** — compilation is split into a size-independent
//!   [`TapeSkeleton`] and a cheap per-`DimSizes` bind; [`TapeCache`]
//!   shares skeletons across executions that differ only in block
//!   counts, which is exactly the autotuner's measured-trial loop.

pub mod engine;
pub mod kernels;
pub mod pool;
pub mod reference;
pub mod sched;

use crate::ir::dim::DimSizes;
use crate::ir::graph::Graph;
use crate::loopir::compile::{compile_skeleton, specialize_skeleton, TapeSkeleton};
use crate::loopir::interp::{exec, BufVal, ExecConfig, ExecResult, MemSim};
use crate::loopir::lower::lower;
use crate::loopir::LoopIr;
use crate::tensor::{Mat, Val};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// Which executor runs a lowered block program.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, Default)]
pub enum ExecBackend {
    /// Tree-walking interpreter — the semantic ground truth.
    #[default]
    Interp,
    /// Flat-tape engine with multi-threaded grid loops.
    Compiled,
    /// The compiled engine running a kernel-specialized tape: at bind
    /// time, [`crate::loopir::compile::specialize_skeleton`] replaces
    /// recognized instruction regions with pre-monomorphized fused loop
    /// bodies from the [`kernels`] registry, so dispatch is resolved
    /// once per site instead of per element. Bit-identical to the other
    /// two backends (outputs *and* counters) — only dispatch moves.
    Specialized,
}

impl ExecBackend {
    pub fn from_name(s: &str) -> Option<ExecBackend> {
        match s {
            "interp" | "interpreter" => Some(ExecBackend::Interp),
            "compiled" | "engine" | "tape" => Some(ExecBackend::Compiled),
            "specialized" | "spec" | "fused" => Some(ExecBackend::Specialized),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecBackend::Interp => "interp",
            ExecBackend::Compiled => "compiled",
            ExecBackend::Specialized => "specialized",
        }
    }
}

/// Execute a lowered program under `cfg` on the chosen backend.
///
/// `Compiled` flattens the tape on each call; callers that execute one
/// program many times under the *same* config (benches, measurement
/// loops) can amortize by calling `loopir::compile::compile` once and
/// `engine::exec_compiled` per run; callers that vary only `DimSizes`
/// across runs should go through [`TapeCache`] instead.
pub fn exec_ir(ir: &LoopIr, cfg: &ExecConfig, backend: ExecBackend) -> ExecResult {
    match backend {
        ExecBackend::Interp => exec(ir, cfg),
        ExecBackend::Compiled => {
            let prog = crate::loopir::compile::compile(ir, cfg);
            engine::exec_compiled(&prog, cfg)
        }
        ExecBackend::Specialized => {
            let skel = specialize_skeleton(&compile_skeleton(ir, cfg));
            let prog = skel.bind(&cfg.sizes);
            engine::exec_compiled(&prog, cfg)
        }
    }
}

/// Cross-trial compiled-tape cache, keyed by **program structure** (the
/// full structural dump of the Loop IR plus scalar params — everything
/// except `DimSizes`) and the [`ExecBackend`] **enum value** — not its
/// name string, so no two backend variants (today or added later) can
/// ever alias one entry even if their display names collide; a
/// `Specialized` skeleton (carrying `Instr::Fused` rewrites) can never
/// be served to a `Compiled` caller or vice versa. The structural key
/// stores the dump itself, not a hash of it, so two distinct programs
/// can never alias either.
///
/// The autotuner probes one lowered program under many block-count
/// assignments; without the cache every trial re-ran the whole
/// compilation (operator resolution, elementwise-expression compilation,
/// parallel-safety analysis, tape layout). With it, the size-independent
/// [`TapeSkeleton`] is built once per structure and each trial only
/// re-binds trip counts and stride tables ([`TapeSkeleton::bind`]).
/// For [`ExecBackend::Specialized`], the kernel-specialization pass
/// ([`specialize_skeleton`]) runs once here too — per-size binds reuse
/// the specialized skeleton.
///
/// The misc-op registries are resolved into the skeleton but not part of
/// the key: use one cache per registry (every current caller does).
pub struct TapeCache {
    entries: HashMap<(String, ExecBackend), Arc<TapeSkeleton>>,
    /// Lookups served from the cache (telemetry for tests/benches).
    pub hits: u64,
    /// Lookups that compiled a fresh skeleton.
    pub misses: u64,
}

impl TapeCache {
    pub fn new() -> TapeCache {
        TapeCache {
            entries: HashMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Structural key: buffers, body, var count, and scalar params (dims
    /// appear by *name* only, so all `DimSizes` bindings of one program
    /// share a key). Exact — compared by equality, never by hash alone.
    fn fingerprint(ir: &LoopIr, cfg: &ExecConfig) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = write!(s, "{:?}|{:?}|{}", ir.bufs, ir.body, ir.n_vars);
        for (k, v) in &cfg.params {
            let _ = write!(s, "|{k}={:08x}", v.to_bits());
        }
        s
    }

    /// The skeleton for `ir` under `cfg`'s params, compiled at most once
    /// per (structure, backend) key.
    pub fn skeleton(
        &mut self,
        ir: &LoopIr,
        cfg: &ExecConfig,
        backend: ExecBackend,
    ) -> Arc<TapeSkeleton> {
        let key = (Self::fingerprint(ir, cfg), backend);
        if let Some(s) = self.entries.get(&key) {
            self.hits += 1;
            return s.clone();
        }
        self.misses += 1;
        let mut skel = compile_skeleton(ir, cfg);
        if backend == ExecBackend::Specialized {
            skel = specialize_skeleton(&skel);
        }
        let s = Arc::new(skel);
        self.entries.insert(key, s.clone());
        s
    }

    /// Number of distinct (structure, backend) entries held.
    pub fn entries(&self) -> usize {
        self.entries.len()
    }
}

impl Default for TapeCache {
    fn default() -> Self {
        TapeCache::new()
    }
}

/// Split a matrix into an `rb × cb` grid of blocks (sizes must divide).
pub fn to_blocks(m: &Mat, rb: usize, cb: usize) -> BufVal {
    assert!(
        m.rows % rb == 0 && m.cols % cb == 0,
        "matrix {}x{} not divisible into {rb}x{cb} blocks",
        m.rows,
        m.cols
    );
    let (bh, bw) = (m.rows / rb, m.cols / cb);
    let mut bv = BufVal::new(vec![rb, cb]);
    for i in 0..rb {
        for j in 0..cb {
            bv.set(&[i, j], Val::Block(m.slice(i * bh, j * bw, bh, bw)));
        }
    }
    bv
}

/// Append `part` to a *stateful* buffer (a KV cache) along `axis`
/// (0 = new rows below, 1 = new columns to the right), charging the
/// incremental traffic to `mem`.
///
/// This is the write half of the stateful-buffer contract: a decode
/// step stores only the block(s) it appends — `part` — instead of
/// re-materializing the whole cache, so the charge is `part.bytes()`
/// (plus `blocks = (rb, cb)` store events, the block granularity of
/// the append). The same bytes are also recorded in the
/// `MemSim::state_appended_bytes` / `state_appends` breakout so a
/// decode step's counters reconcile exactly against its stateless
/// equivalent: `stored == stateless.stored + state_appended_bytes`.
///
/// Growing from empty is allowed (a `rows×0` or `0×cols` cache); the
/// off-axis extent must already match.
pub fn append_state(
    cache: &mut Mat,
    axis: usize,
    part: &Mat,
    blocks: (usize, usize),
    mem: &mut MemSim,
) {
    match axis {
        0 => {
            assert!(
                cache.cols == part.cols,
                "append_state axis 0: cache has {} cols, part has {}",
                cache.cols,
                part.cols
            );
            cache.data.extend_from_slice(&part.data);
            cache.rows += part.rows;
        }
        1 => {
            assert!(
                cache.rows == part.rows,
                "append_state axis 1: cache has {} rows, part has {}",
                cache.rows,
                part.rows
            );
            let (oldc, newc) = (cache.cols, cache.cols + part.cols);
            let mut data = Vec::with_capacity(cache.rows * newc);
            for i in 0..cache.rows {
                data.extend_from_slice(&cache.data[i * oldc..(i + 1) * oldc]);
                data.extend_from_slice(part.row(i));
            }
            cache.data = data;
            cache.cols = newc;
        }
        _ => panic!("append_state: axis {axis} out of range for a matrix"),
    }
    let n_blocks = (blocks.0 * blocks.1) as u64;
    mem.stored_bytes += part.bytes() as u64;
    mem.n_stores += n_blocks;
    mem.state_appended_bytes += part.bytes() as u64;
    mem.state_appends += n_blocks;
}

/// Reassemble a `[rb, cb]` grid of blocks into one matrix.
pub fn from_blocks(bv: &BufVal) -> Mat {
    assert_eq!(bv.dims.len(), 2, "from_blocks needs a 2-d block grid");
    let (rb, cb) = (bv.dims[0], bv.dims[1]);
    let b00 = bv.get(&[0, 0]).as_block();
    let (bh, bw) = (b00.rows, b00.cols);
    let mut out = Mat::zeros(rb * bh, cb * bw);
    for i in 0..rb {
        for j in 0..cb {
            out.place(i * bh, j * bw, bv.get(&[i, j]).as_block());
        }
    }
    out
}

/// Stack same-shaped block grids along `axis`: part `r`'s element at
/// `axis`-coordinate `x` lands at coordinate `r·d + x` of the stacked
/// grid (`d` = the per-part extent). Payloads are `Arc`-shared, so
/// stacking moves pointers, never block data — the serving layer uses
/// this to coalesce a batch of requests into one enlarged launch.
pub fn stack_blocks(parts: &[BufVal], axis: usize) -> BufVal {
    let first = parts.first().expect("stack_blocks: empty part list");
    assert!(
        axis < first.dims.len(),
        "stack_blocks: axis {axis} out of rank {}",
        first.dims.len()
    );
    let mut dims = first.dims.clone();
    dims[axis] *= parts.len();
    let mut out = BufVal::new(dims);
    let d = first.dims[axis];
    for (r, p) in parts.iter().enumerate() {
        assert_eq!(p.dims, first.dims, "stack_blocks: part {r} shape differs");
        for (flat, v) in p.data.iter().enumerate() {
            out.data[offset_flat(flat, &p.dims, &out.dims, axis, r * d)] = v.clone();
        }
    }
    out
}

/// [`stack_blocks`] for *ragged* parts: grids may differ in their
/// `axis` extent (every other extent must agree), and part `r` lands at
/// the running offset of the extents before it. The serving layer's
/// shape-bucketed batches use this to stack requests whose stackable
/// grid dim differs per request (optionally interleaved with zero pad
/// grids). Pointer moves only, like the uniform case.
pub fn stack_blocks_ragged(parts: &[BufVal], axis: usize) -> BufVal {
    let first = parts.first().expect("stack_blocks_ragged: empty part list");
    assert!(
        axis < first.dims.len(),
        "stack_blocks_ragged: axis {axis} out of rank {}",
        first.dims.len()
    );
    let mut dims = first.dims.clone();
    dims[axis] = parts.iter().map(|p| p.dims[axis]).sum();
    let mut out = BufVal::new(dims);
    let mut off = 0usize;
    for (r, p) in parts.iter().enumerate() {
        for (i, (&a, &b)) in p.dims.iter().zip(&first.dims).enumerate() {
            assert!(
                i == axis || a == b,
                "stack_blocks_ragged: part {r} differs from part 0 on non-stack axis {i}"
            );
        }
        for (flat, v) in p.data.iter().enumerate() {
            out.data[offset_flat(flat, &p.dims, &out.dims, axis, off)] = v.clone();
        }
        off += p.dims[axis];
    }
    out
}

/// Inverse of [`stack_blocks_ragged`]: the slab of `len` `axis`-slices
/// starting at coordinate `lo` (pointer copies). Ragged de-stacking —
/// request `r` of a shape-bucketed batch recovers exactly its own rows,
/// dropping any pad slices around it.
pub fn unstack_blocks_range(stacked: &BufVal, axis: usize, lo: usize, len: usize) -> BufVal {
    assert!(
        axis < stacked.dims.len() && lo + len <= stacked.dims[axis],
        "unstack_blocks_range: [{lo}, {lo}+{len}) out of extent {} on axis {axis}",
        stacked.dims[axis]
    );
    let mut dims = stacked.dims.clone();
    dims[axis] = len;
    let mut out = BufVal::new(dims.clone());
    for (flat, slot) in out.data.iter_mut().enumerate() {
        *slot = stacked.data[offset_flat(flat, &dims, &stacked.dims, axis, lo)].clone();
    }
    out
}

/// Inverse of [`stack_blocks`]: slice `r` of `parts` equal slabs along
/// `axis` (pointer copies, like stacking).
pub fn unstack_blocks(stacked: &BufVal, axis: usize, parts: usize, r: usize) -> BufVal {
    assert!(axis < stacked.dims.len() && r < parts, "unstack_blocks: bad axis/slice");
    assert_eq!(
        stacked.dims[axis] % parts,
        0,
        "unstack_blocks: extent {} does not divide into {parts} slabs",
        stacked.dims[axis]
    );
    let mut dims = stacked.dims.clone();
    dims[axis] /= parts;
    let d = dims[axis];
    let mut out = BufVal::new(dims.clone());
    for (flat, slot) in out.data.iter_mut().enumerate() {
        *slot = stacked.data[offset_flat(flat, &dims, &stacked.dims, axis, r * d)].clone();
    }
    out
}

/// Row-major flat index in a `big`-shaped grid of the element whose
/// coordinates equal those of `flat` in the `small`-shaped grid, with
/// `offset` added on `axis` (all other extents must agree). Fixed
/// scratch, no allocation — this runs once per block pointer of every
/// coalesced batch (same rank-≤8 convention as the interpreter's index
/// scratch).
fn offset_flat(flat: usize, small: &[usize], big: &[usize], axis: usize, offset: usize) -> usize {
    assert!(small.len() <= 8, "block grids are rank <= 8");
    let mut rem = flat;
    let mut coords = [0usize; 8];
    for i in (0..small.len()).rev() {
        coords[i] = rem % small[i];
        rem /= small[i];
    }
    coords[axis] += offset;
    let mut f = 0;
    for (i, &e) in big.iter().enumerate() {
        f = f * e + coords[i];
    }
    f
}

/// A ready-to-run workload: dim sizes (block counts), scalar params, full
/// input matrices, optional local-memory capacity, optional worker cap.
pub struct Workload {
    pub sizes: DimSizes,
    pub params: BTreeMap<String, f32>,
    pub inputs: HashMap<String, Mat>,
    pub local_capacity: Option<u64>,
    /// Worker cap for the compiled engine's parallel grid loops (`None`
    /// = one per available core); the interpreter ignores it.
    pub threads: Option<usize>,
}

impl Workload {
    pub fn new(sizes: DimSizes) -> Workload {
        Workload {
            sizes,
            params: BTreeMap::new(),
            inputs: HashMap::new(),
            local_capacity: None,
            threads: None,
        }
    }

    pub fn input(mut self, name: &str, m: Mat) -> Self {
        self.inputs.insert(name.into(), m);
        self
    }

    pub fn param(mut self, name: &str, v: f32) -> Self {
        self.params.insert(name.into(), v);
        self
    }

    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }
}

/// Result of running a block program on a workload.
pub struct RunResult {
    pub outputs: HashMap<String, Mat>,
    pub mem: MemSim,
}

/// Lower and run a block program on full-matrix inputs (interpreter).
pub fn run(g: &Graph, w: &Workload) -> RunResult {
    run_lowered(&lower(g), w)
}

/// Lower and run on the chosen backend.
pub fn run_with(g: &Graph, w: &Workload, backend: ExecBackend) -> RunResult {
    run_lowered_with(&lower(g), w, backend)
}

/// Run an already-lowered program (lets benches amortize lowering).
pub fn run_lowered(ir: &LoopIr, w: &Workload) -> RunResult {
    run_lowered_with(ir, w, ExecBackend::Interp)
}

/// Build the blocked `ExecConfig` for a workload (splitting every full
/// input matrix into its block grid).
fn build_cfg(ir: &LoopIr, w: &Workload) -> ExecConfig {
    let mut cfg = ExecConfig::new(w.sizes.clone());
    cfg.params = w.params.clone();
    cfg.local_capacity = w.local_capacity;
    cfg.threads = w.threads;
    for decl in &ir.bufs {
        if !decl.is_input {
            continue;
        }
        let m = w
            .inputs
            .get(&decl.name)
            .unwrap_or_else(|| panic!("workload missing input {}", decl.name));
        assert_eq!(
            decl.dims.len(),
            2,
            "program input {} must be 2-d blocked",
            decl.name
        );
        let rb = w.sizes.get(&decl.dims[0]);
        let cb = w.sizes.get(&decl.dims[1]);
        cfg.inputs.insert(decl.name.clone(), to_blocks(m, rb, cb));
    }
    cfg
}

fn unblock(res: ExecResult) -> RunResult {
    let outputs = res
        .outputs
        .iter()
        .map(|(name, bv)| (name.clone(), from_blocks(bv)))
        .collect();
    RunResult {
        outputs,
        mem: res.mem,
    }
}

/// Run an already-lowered program on the chosen backend.
pub fn run_lowered_with(ir: &LoopIr, w: &Workload, backend: ExecBackend) -> RunResult {
    let cfg = build_cfg(ir, w);
    unblock(exec_ir(ir, &cfg, backend))
}

/// Like [`run_lowered_with`], but the compiled backend pulls its tape
/// skeleton from `cache` and only binds the workload's `DimSizes` —
/// the autotuner's measured-trial path.
pub fn run_lowered_cached(
    ir: &LoopIr,
    w: &Workload,
    backend: ExecBackend,
    cache: &mut TapeCache,
) -> RunResult {
    let cfg = build_cfg(ir, w);
    let res = match backend {
        ExecBackend::Interp => exec(ir, &cfg),
        // The cache already holds the right skeleton flavor per backend
        // key — specialization ran on the miss path for `Specialized`.
        ExecBackend::Compiled | ExecBackend::Specialized => {
            let skel = cache.skeleton(ir, &cfg, backend);
            let prog = skel.bind(&cfg.sizes);
            engine::exec_compiled(&prog, &cfg)
        }
    };
    unblock(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn block_roundtrip() {
        let mut rng = Rng::new(5);
        let m = rng.mat(6, 8);
        let bv = to_blocks(&m, 3, 2);
        assert_eq!(bv.dims, vec![3, 2]);
        let back = from_blocks(&bv);
        assert_eq!(back, m);
    }

    /// Stacking block grids along either axis round-trips through
    /// unstacking, and a vertical stack of matrices equals blocking the
    /// vertically concatenated matrix.
    #[test]
    fn stack_unstack_roundtrip() {
        let mut rng = Rng::new(11);
        let mats: Vec<Mat> = (0..3).map(|_| rng.mat(4, 6)).collect();
        for axis in [0usize, 1] {
            let parts: Vec<BufVal> = mats.iter().map(|m| to_blocks(m, 2, 3)).collect();
            let stacked = stack_blocks(&parts, axis);
            let mut want = vec![2usize, 3];
            want[axis] *= 3;
            assert_eq!(stacked.dims, want);
            for (r, m) in mats.iter().enumerate() {
                let back = unstack_blocks(&stacked, axis, 3, r);
                assert_eq!(&from_blocks(&back), m, "axis {axis} slice {r}");
            }
        }
        // vertical stack == blocking the row-concatenated matrix
        let parts: Vec<BufVal> = mats.iter().map(|m| to_blocks(m, 2, 3)).collect();
        let stacked = stack_blocks(&parts, 0);
        let mut cat = Mat::zeros(12, 6);
        for (r, m) in mats.iter().enumerate() {
            cat.place(r * 4, 0, m);
        }
        assert_eq!(from_blocks(&stacked), cat);
    }

    /// Ragged stacking: parts with different extents along the stack
    /// axis concatenate at running offsets, and range de-stacking
    /// recovers each part exactly — including with zero-extent pads
    /// interleaved (the pad-to-bucket layout).
    #[test]
    fn ragged_stack_and_range_unstack_roundtrip() {
        let mut rng = Rng::new(17);
        // row-block counts 1, 3, 2 over 4x6 / 12x6 / 8x6 matrices
        let mats: Vec<Mat> = [1usize, 3, 2].iter().map(|&k| rng.mat(4 * k, 6)).collect();
        let parts: Vec<BufVal> = mats.iter().map(|m| to_blocks(m, m.rows / 4, 3)).collect();
        let stacked = stack_blocks_ragged(&parts, 0);
        assert_eq!(stacked.dims, vec![6, 3]);
        let mut lo = 0usize;
        for (r, m) in mats.iter().enumerate() {
            let k = m.rows / 4;
            let back = unstack_blocks_range(&stacked, 0, lo, k);
            assert_eq!(&from_blocks(&back), m, "part {r}");
            lo += k;
        }
        // row-concatenation equivalence, as in the uniform test
        let total: usize = mats.iter().map(|m| m.rows).sum();
        let mut cat = Mat::zeros(total, 6);
        let mut row = 0;
        for m in &mats {
            cat.place(row, 0, m);
            row += m.rows;
        }
        assert_eq!(from_blocks(&stacked), cat);

        // interleave a pad grid and check the ranges still line up
        let pad = to_blocks(&Mat::zeros(8, 6), 2, 3);
        let with_pad = stack_blocks_ragged(
            &[parts[0].clone(), pad, parts[1].clone()],
            0,
        );
        assert_eq!(with_pad.dims, vec![6, 3]);
        assert_eq!(&from_blocks(&unstack_blocks_range(&with_pad, 0, 0, 1)), &mats[0]);
        assert_eq!(&from_blocks(&unstack_blocks_range(&with_pad, 0, 3, 3)), &mats[1]);
    }

    #[test]
    #[should_panic(expected = "non-stack axis")]
    fn ragged_stack_rejects_non_stack_axis_mismatch() {
        let mut rng = Rng::new(19);
        let a = to_blocks(&rng.mat(4, 6), 2, 3);
        let b = to_blocks(&rng.mat(4, 4), 2, 2);
        let _ = stack_blocks_ragged(&[a, b], 0);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn indivisible_blocks_panic() {
        let mut rng = Rng::new(5);
        let m = rng.mat(5, 8);
        to_blocks(&m, 3, 2);
    }

    /// The tape cache: one skeleton compile per program structure, and
    /// cached executions bit-identical to uncached ones across different
    /// `DimSizes` bindings of the same program.
    #[test]
    fn tape_cache_rebinds_across_sizes() {
        use crate::ir::expr::Expr;
        use crate::ir::graph::{map_over, ArgMode};
        let mut g = Graph::new();
        let a = g.input("A", crate::ir::types::Ty::blocks(&["M", "N"]));
        let o = map_over(&mut g, "M", &[(a, ArgMode::Mapped)], |mb, ins| {
            let inner = map_over(&mut mb.g, "N", &[(ins[0], ArgMode::Mapped)], |mb2, ins2| {
                let r = mb2.g.ew1(Expr::var(0).exp(), ins2[0]);
                mb2.collect(r);
            });
            mb.collect(inner[0]);
        });
        g.output("B", o[0]);
        let ir = lower(&g);

        let mut rng = Rng::new(13);
        let input = rng.mat(16, 16);
        let mut cache = TapeCache::new();
        for (mb, nb) in [(2usize, 4usize), (4, 2), (8, 8)] {
            let w = Workload::new(DimSizes::of(&[("M", mb), ("N", nb)]))
                .input("A", input.clone())
                .threads(2);
            let plain = run_lowered_with(&ir, &w, ExecBackend::Compiled);
            let cached = run_lowered_cached(&ir, &w, ExecBackend::Compiled, &mut cache);
            assert_eq!(plain.outputs["B"], cached.outputs["B"], "({mb},{nb})");
            assert_eq!(plain.mem.loaded_bytes, cached.mem.loaded_bytes);
            assert_eq!(plain.mem.flops, cached.mem.flops);
        }
        assert_eq!(cache.misses, 1, "one skeleton for all three bindings");
        assert_eq!(cache.hits, 2);
    }

    /// The cardinal invariant at unit scope: the specialized tape is
    /// bit-identical to the generic one — outputs and every MemSim
    /// counter — single-threaded and under the pool.
    #[test]
    fn specialized_backend_bitwise_matches_compiled() {
        use crate::ir::expr::Expr;
        use crate::ir::graph::{map_over, ArgMode};
        let mut g = Graph::new();
        let a = g.input("A", crate::ir::types::Ty::blocks(&["M", "N"]));
        let o = map_over(&mut g, "M", &[(a, ArgMode::Mapped)], |mb, ins| {
            let inner = map_over(&mut mb.g, "N", &[(ins[0], ArgMode::Mapped)], |mb2, ins2| {
                let r = mb2.g.ew1(Expr::var(0).exp(), ins2[0]);
                mb2.collect(r);
            });
            mb.collect(inner[0]);
        });
        g.output("B", o[0]);
        let ir = lower(&g);

        let mut rng = Rng::new(29);
        let input = rng.mat(16, 16);
        for threads in [1usize, 4] {
            let w = Workload::new(DimSizes::of(&[("M", 4), ("N", 4)]))
                .input("A", input.clone())
                .threads(threads);
            let c = run_lowered_with(&ir, &w, ExecBackend::Compiled);
            let s = run_lowered_with(&ir, &w, ExecBackend::Specialized);
            assert_eq!(c.outputs["B"], s.outputs["B"], "threads {threads}");
            assert_eq!(c.mem, s.mem, "threads {threads}");
        }
    }

    /// Satellite audit: the cache key pins the backend **enum**, so one
    /// program bound under all three backends yields three distinct
    /// entries — a `Specialized` skeleton (with its `Instr::Fused`
    /// rewrites) can never be served to a `Compiled` caller. Hit counts
    /// stay stable on re-request.
    #[test]
    fn tape_cache_keys_pin_backend_variant() {
        use crate::ir::expr::Expr;
        use crate::ir::graph::{map_over, ArgMode};
        let mut g = Graph::new();
        let a = g.input("A", crate::ir::types::Ty::blocks(&["M", "N"]));
        let o = map_over(&mut g, "M", &[(a, ArgMode::Mapped)], |mb, ins| {
            let inner = map_over(&mut mb.g, "N", &[(ins[0], ArgMode::Mapped)], |mb2, ins2| {
                let r = mb2.g.ew1(Expr::var(0).exp(), ins2[0]);
                mb2.collect(r);
            });
            mb.collect(inner[0]);
        });
        g.output("B", o[0]);
        let ir = lower(&g);
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 2), ("N", 4)]));

        let backends = [
            ExecBackend::Interp,
            ExecBackend::Compiled,
            ExecBackend::Specialized,
        ];
        let mut cache = TapeCache::new();
        let skels: Vec<_> = backends
            .iter()
            .map(|b| cache.skeleton(&ir, &cfg, *b))
            .collect();
        assert_eq!(cache.entries(), 3, "one entry per backend variant");
        assert_eq!(cache.misses, 3);
        assert_eq!(cache.hits, 0);
        for b in backends {
            cache.skeleton(&ir, &cfg, b);
        }
        assert_eq!(cache.hits, 3, "re-requests hit, never recompile");
        assert_eq!(cache.misses, 3);
        // specialization state rides the entry, not just the key
        assert!(skels[2].spec.is_some(), "specialized entry carries its report");
        assert!(skels[1].spec.is_none(), "compiled entry stays generic");
        assert!(skels[0].spec.is_none());
    }
}
