//! High-level execution of block programs on full matrices.
//!
//! Bridges the gap between logical matrices and the blocked representation:
//! splits each program input into its `[rows, cols]` grid of blocks,
//! executes the lowered Loop IR under the two-tier memory simulator, and
//! reassembles block-matrix outputs. Also hosts the tensor-level reference
//! implementations used to cross-check every example program.
//!
//! Two interchangeable backends execute the Loop IR ([`ExecBackend`]):
//!
//! * [`ExecBackend::Interp`] — the tree-walking interpreter
//!   (`loopir::interp`), the semantic ground truth;
//! * [`ExecBackend::Compiled`] — `loopir::compile` flattens the program to
//!   an instruction tape that [`engine`] executes, fanning independent
//!   grid-loop iterations across threads. Outputs and traffic counters are
//!   bit-identical to the interpreter; wall-clock is several times faster,
//!   which is what makes autotune trials and large benches tractable.

pub mod engine;
pub mod reference;

use crate::ir::dim::DimSizes;
use crate::ir::graph::Graph;
use crate::loopir::interp::{exec, BufVal, ExecConfig, ExecResult, MemSim};
use crate::loopir::lower::lower;
use crate::loopir::LoopIr;
use crate::tensor::{Mat, Val};
use std::collections::{BTreeMap, HashMap};

/// Which executor runs a lowered block program.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum ExecBackend {
    /// Tree-walking interpreter — the semantic ground truth.
    #[default]
    Interp,
    /// Flat-tape engine with multi-threaded grid loops.
    Compiled,
}

impl ExecBackend {
    pub fn from_name(s: &str) -> Option<ExecBackend> {
        match s {
            "interp" | "interpreter" => Some(ExecBackend::Interp),
            "compiled" | "engine" | "tape" => Some(ExecBackend::Compiled),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecBackend::Interp => "interp",
            ExecBackend::Compiled => "compiled",
        }
    }
}

/// Execute a lowered program under `cfg` on the chosen backend.
///
/// `Compiled` flattens the tape on each call; callers that execute one
/// program many times under the *same* config (benches, measurement
/// loops) can amortize by calling `loopir::compile::compile` once and
/// `engine::exec_compiled` per run.
pub fn exec_ir(ir: &LoopIr, cfg: &ExecConfig, backend: ExecBackend) -> ExecResult {
    match backend {
        ExecBackend::Interp => exec(ir, cfg),
        ExecBackend::Compiled => {
            let prog = crate::loopir::compile::compile(ir, cfg);
            engine::exec_compiled(&prog, cfg)
        }
    }
}

/// Split a matrix into an `rb × cb` grid of blocks (sizes must divide).
pub fn to_blocks(m: &Mat, rb: usize, cb: usize) -> BufVal {
    assert!(
        m.rows % rb == 0 && m.cols % cb == 0,
        "matrix {}x{} not divisible into {rb}x{cb} blocks",
        m.rows,
        m.cols
    );
    let (bh, bw) = (m.rows / rb, m.cols / cb);
    let mut bv = BufVal::new(vec![rb, cb]);
    for i in 0..rb {
        for j in 0..cb {
            bv.set(&[i, j], Val::Block(m.slice(i * bh, j * bw, bh, bw)));
        }
    }
    bv
}

/// Reassemble a `[rb, cb]` grid of blocks into one matrix.
pub fn from_blocks(bv: &BufVal) -> Mat {
    assert_eq!(bv.dims.len(), 2, "from_blocks needs a 2-d block grid");
    let (rb, cb) = (bv.dims[0], bv.dims[1]);
    let b00 = bv.get(&[0, 0]).as_block();
    let (bh, bw) = (b00.rows, b00.cols);
    let mut out = Mat::zeros(rb * bh, cb * bw);
    for i in 0..rb {
        for j in 0..cb {
            out.place(i * bh, j * bw, bv.get(&[i, j]).as_block());
        }
    }
    out
}

/// A ready-to-run workload: dim sizes (block counts), scalar params, full
/// input matrices, optional local-memory capacity.
pub struct Workload {
    pub sizes: DimSizes,
    pub params: BTreeMap<String, f32>,
    pub inputs: HashMap<String, Mat>,
    pub local_capacity: Option<u64>,
}

impl Workload {
    pub fn new(sizes: DimSizes) -> Workload {
        Workload {
            sizes,
            params: BTreeMap::new(),
            inputs: HashMap::new(),
            local_capacity: None,
        }
    }

    pub fn input(mut self, name: &str, m: Mat) -> Self {
        self.inputs.insert(name.into(), m);
        self
    }

    pub fn param(mut self, name: &str, v: f32) -> Self {
        self.params.insert(name.into(), v);
        self
    }
}

/// Result of running a block program on a workload.
pub struct RunResult {
    pub outputs: HashMap<String, Mat>,
    pub mem: MemSim,
}

/// Lower and run a block program on full-matrix inputs (interpreter).
pub fn run(g: &Graph, w: &Workload) -> RunResult {
    run_lowered(&lower(g), w)
}

/// Lower and run on the chosen backend.
pub fn run_with(g: &Graph, w: &Workload, backend: ExecBackend) -> RunResult {
    run_lowered_with(&lower(g), w, backend)
}

/// Run an already-lowered program (lets benches amortize lowering).
pub fn run_lowered(ir: &LoopIr, w: &Workload) -> RunResult {
    run_lowered_with(ir, w, ExecBackend::Interp)
}

/// Run an already-lowered program on the chosen backend.
pub fn run_lowered_with(ir: &LoopIr, w: &Workload, backend: ExecBackend) -> RunResult {
    let mut cfg = ExecConfig::new(w.sizes.clone());
    cfg.params = w.params.clone();
    cfg.local_capacity = w.local_capacity;
    for decl in &ir.bufs {
        if !decl.is_input {
            continue;
        }
        let m = w
            .inputs
            .get(&decl.name)
            .unwrap_or_else(|| panic!("workload missing input {}", decl.name));
        assert_eq!(
            decl.dims.len(),
            2,
            "program input {} must be 2-d blocked",
            decl.name
        );
        let rb = w.sizes.get(&decl.dims[0]);
        let cb = w.sizes.get(&decl.dims[1]);
        cfg.inputs.insert(decl.name.clone(), to_blocks(m, rb, cb));
    }
    let res = exec_ir(ir, &cfg, backend);
    let outputs = res
        .outputs
        .iter()
        .map(|(name, bv)| (name.clone(), from_blocks(bv)))
        .collect();
    RunResult {
        outputs,
        mem: res.mem,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn block_roundtrip() {
        let mut rng = Rng::new(5);
        let m = rng.mat(6, 8);
        let bv = to_blocks(&m, 3, 2);
        assert_eq!(bv.dims, vec![3, 2]);
        let back = from_blocks(&bv);
        assert_eq!(back, m);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn indivisible_blocks_panic() {
        let mut rng = Rng::new(5);
        let m = rng.mat(5, 8);
        to_blocks(&m, 3, 2);
    }
}
