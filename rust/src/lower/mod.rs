//! Array-program → block-program conversion (the paper's Table 2).
//!
//! Each array operator is replaced by a predefined block-program subgraph.
//! Every subgraph is **fully unfused** — it materializes all intermediates
//! in global memory "even when a straightforward fusion opportunity is
//! evident" (§2.2); discovering those opportunities is the fusion
//! algorithm's job, and starting unfused is what makes the paper's traces
//! reproducible step for step.
//!
//! Conventions (verified against the §5 walkthroughs):
//! * every operator's subgraph is wrapped in a map over the *row-block* dim
//!   of its output ("matrix multiplication operators become a single block
//!   operator [at top level]… softmax becomes four");
//! * matmul inside the row map is `Map(n){ Map(k){dot} → Reduce(k) }`;
//! * softmax = exp-map, rowsum-map, (reduce+reciprocal)-map, scale-map;
//! * layernorm = rowsum, (reduce → −s/KK), shift, square, rowsum,
//!   (reduce → (s₂/KK − μ²)^(−1/2)), scale — seven operators;
//! * rmsnorm = square, rowsum, (reduce → 1/sqrt(s/DD)), scale — four.

use crate::array::{AOp, ANodeId, ArrayProgram};
use crate::ir::dim::Dim;
use crate::ir::expr::Expr;
use crate::ir::func::{FuncOp, ReduceOp};
use crate::ir::graph::{map_over, ArgMode, Graph, NodeKind, Port};
use crate::ir::types::Ty;
use crate::rules::matmul::build_matmul;
use std::collections::HashMap;

/// Convert an array program into its initial (fully unfused) block program.
pub fn lower_array(p: &ArrayProgram) -> Graph {
    let mut g = Graph::new();
    let mut val: HashMap<ANodeId, Port> = HashMap::new();

    for (id, n) in p.nodes.iter().enumerate() {
        let m = n.blocking.rows.name().to_string();
        let out: Port = match &n.op {
            AOp::Input { name, .. } => g.input(
                name.clone(),
                Ty::blocks(&[n.blocking.rows.name(), n.blocking.cols.name()]),
            ),
            AOp::MatMul => {
                let a = val[&n.inputs[0]];
                let bt = val[&n.inputs[1]];
                let a_blk = p.nodes[n.inputs[0]].blocking.clone();
                let b_blk = p.nodes[n.inputs[1]].blocking.clone();
                let (n_dim, k_dim) = (b_blk.rows.name().to_string(), a_blk.cols.name().to_string());
                let outs = map_over(
                    &mut g,
                    m.as_str(),
                    &[(a, ArgMode::Mapped), (bt, ArgMode::Bcast)],
                    |mb, ins| {
                        let o = build_matmul(&mut mb.g, ins[0], ins[1], &n_dim, &k_dim);
                        mb.collect(o);
                    },
                );
                outs[0]
            }
            AOp::Ew { expr, .. } => {
                let a = val[&n.inputs[0]];
                let c = n.blocking.cols.name().to_string();
                let e = expr.clone();
                let outs = map_over(&mut g, m.as_str(), &[(a, ArgMode::Mapped)], |mb, ins| {
                    let inner = map_over(
                        &mut mb.g,
                        c.as_str(),
                        &[(ins[0], ArgMode::Mapped)],
                        |mb2, i2| {
                            let r = mb2.g.ew1(e.clone(), i2[0]);
                            mb2.collect(r);
                        },
                    );
                    mb.collect(inner[0]);
                });
                outs[0]
            }
            AOp::Hadamard | AOp::Add => {
                let a = val[&n.inputs[0]];
                let b = val[&n.inputs[1]];
                let c = n.blocking.cols.name().to_string();
                let f = if matches!(n.op, AOp::Hadamard) {
                    FuncOp::Mul
                } else {
                    FuncOp::Add
                };
                let outs = map_over(
                    &mut g,
                    m.as_str(),
                    &[(a, ArgMode::Mapped), (b, ArgMode::Mapped)],
                    |mb, ins| {
                        let ff = f.clone();
                        let inner = map_over(
                            &mut mb.g,
                            c.as_str(),
                            &[(ins[0], ArgMode::Mapped), (ins[1], ArgMode::Mapped)],
                            move |mb2, i2| {
                                let r = mb2.g.func(ff, &[i2[0], i2[1]]);
                                mb2.collect(r);
                            },
                        );
                        mb.collect(inner[0]);
                    },
                );
                outs[0]
            }
            AOp::Softmax => lower_softmax(&mut g, val[&n.inputs[0]], &m, n.blocking.cols.name()),
            AOp::LayerNorm => {
                let kk = p.row_len_param(id);
                lower_layernorm(&mut g, val[&n.inputs[0]], &m, n.blocking.cols.name(), &kk)
            }
            AOp::RmsNorm => {
                let dd = p.row_len_param(id);
                lower_rmsnorm(&mut g, val[&n.inputs[0]], &m, n.blocking.cols.name(), &dd)
            }
            AOp::Custom { tag } => {
                let ins: Vec<Port> = n.inputs.iter().map(|i| val[i]).collect();
                let in_tys: Vec<Ty> = ins.iter().map(|p| g.out_ty(*p)).collect();
                let out_ty = Ty::blocks(&[n.blocking.rows.name(), n.blocking.cols.name()]);
                let id = g.add_node(
                    NodeKind::Misc {
                        tag: tag.clone(),
                        in_tys,
                        out_tys: vec![out_ty],
                    },
                    format!("misc:{tag}"),
                );
                for (i, s) in ins.iter().enumerate() {
                    g.connect(*s, crate::ir::graph::port(id, i));
                }
                crate::ir::graph::port(id, 0)
            }
        };
        val.insert(id, out);
    }

    for (name, id) in &p.outputs {
        g.output(name.clone(), val[id]);
    }
    // Stateful-input marks ride along: array-level `mark_state` becomes a
    // graph-level mark on the same input label, so fusion/selection can
    // propagate it down to the lowered `BufDecl`s.
    for (name, dim) in &p.state {
        g.mark_state(name.clone(), Dim::new(dim));
    }
    g
}

/// Softmax: four top-level operators (exp, rowsum, reduce+recip, scale).
fn lower_softmax(g: &mut Graph, a: Port, m: &str, n_dim: &str) -> Port {
    // S1: elementwise exp
    let e = map_over(g, m, &[(a, ArgMode::Mapped)], |mb, ins| {
        let inner = map_over(&mut mb.g, n_dim, &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
            let r = mb2.g.ew1(Expr::var(0).exp(), i2[0]);
            mb2.collect(r);
        });
        mb.collect(inner[0]);
    });
    // S2: per-block row sums
    let s = map_over(g, m, &[(e[0], ArgMode::Mapped)], |mb, ins| {
        let inner = map_over(&mut mb.g, n_dim, &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
            let r = mb2.g.func(FuncOp::RowSum, &[i2[0]]);
            mb2.collect(r);
        });
        mb.collect(inner[0]);
    });
    // S3: total + reciprocal
    let r = map_over(g, m, &[(s[0], ArgMode::Mapped)], |mb, ins| {
        let red = mb.g.reduce(ReduceOp::Add, ins[0]);
        let rec = mb.g.ew1(Expr::var(0).recip(), red);
        mb.collect(rec);
    });
    // S4: row-scale by the reciprocal denominator
    let o = map_over(
        g,
        m,
        &[(e[0], ArgMode::Mapped), (r[0], ArgMode::Mapped)],
        |mb, ins| {
            let inner = map_over(
                &mut mb.g,
                n_dim,
                &[(ins[0], ArgMode::Mapped), (ins[1], ArgMode::Bcast)],
                |mb2, i2| {
                    let sc = mb2.g.func(FuncOp::RowScale, &[i2[0], i2[1]]);
                    mb2.collect(sc);
                },
            );
            mb.collect(inner[0]);
        },
    );
    o[0]
}

/// LayerNorm: seven top-level operators, per the Example-2 initial program.
fn lower_layernorm(g: &mut Graph, x: Port, m: &str, k_dim: &str, kk: &str) -> Port {
    // L1: per-block row sums of X
    let l1 = map_over(g, m, &[(x, ArgMode::Mapped)], |mb, ins| {
        let inner = map_over(&mut mb.g, k_dim, &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
            let r = mb2.g.func(FuncOp::RowSum, &[i2[0]]);
            mb2.collect(r);
        });
        mb.collect(inner[0]);
    });
    // L2: negative mean  −s/KK
    let l2 = map_over(g, m, &[(l1[0], ArgMode::Mapped)], |mb, ins| {
        let red = mb.g.reduce(ReduceOp::Add, ins[0]);
        let nm = mb
            .g
            .ew1(Expr::var(0).neg().div(Expr::param(kk)), red);
        mb.collect(nm);
    });
    // L3: shift rows by the negative mean
    let l3 = map_over(
        g,
        m,
        &[(x, ArgMode::Mapped), (l2[0], ArgMode::Mapped)],
        |mb, ins| {
            let inner = map_over(
                &mut mb.g,
                k_dim,
                &[(ins[0], ArgMode::Mapped), (ins[1], ArgMode::Bcast)],
                |mb2, i2| {
                    let r = mb2.g.func(FuncOp::RowShift, &[i2[0], i2[1]]);
                    mb2.collect(r);
                },
            );
            mb.collect(inner[0]);
        },
    );
    // L4: squares
    let l4 = map_over(g, m, &[(x, ArgMode::Mapped)], |mb, ins| {
        let inner = map_over(&mut mb.g, k_dim, &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
            let r = mb2.g.ew1(Expr::var(0).pow(Expr::cst(2.0)), i2[0]);
            mb2.collect(r);
        });
        mb.collect(inner[0]);
    });
    // L5: row sums of squares
    let l5 = map_over(g, m, &[(l4[0], ArgMode::Mapped)], |mb, ins| {
        let inner = map_over(&mut mb.g, k_dim, &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
            let r = mb2.g.func(FuncOp::RowSum, &[i2[0]]);
            mb2.collect(r);
        });
        mb.collect(inner[0]);
    });
    // L6: reciprocal std  (s₂/KK − μ²)^(−1/2)   (μ² = (−s/KK)²)
    let l6 = map_over(
        g,
        m,
        &[(l5[0], ArgMode::Mapped), (l2[0], ArgMode::Mapped)],
        |mb, ins| {
            let red = mb.g.reduce(ReduceOp::Add, ins[0]);
            let std = mb.g.ew2(
                Expr::var(0)
                    .div(Expr::param(kk))
                    .sub(Expr::var(1).pow(Expr::cst(2.0)))
                    .pow(Expr::cst(-0.5)),
                red,
                ins[1],
            );
            mb.collect(std);
        },
    );
    // L7: scale shifted rows by 1/σ
    let l7 = map_over(
        g,
        m,
        &[(l3[0], ArgMode::Mapped), (l6[0], ArgMode::Mapped)],
        |mb, ins| {
            let inner = map_over(
                &mut mb.g,
                k_dim,
                &[(ins[0], ArgMode::Mapped), (ins[1], ArgMode::Bcast)],
                |mb2, i2| {
                    let r = mb2.g.func(FuncOp::RowScale, &[i2[0], i2[1]]);
                    mb2.collect(r);
                },
            );
            mb.collect(inner[0]);
        },
    );
    l7[0]
}

/// RMSNorm: four top-level operators (square, rowsum, reduce+1/sqrt, scale).
fn lower_rmsnorm(g: &mut Graph, x: Port, m: &str, d_dim: &str, dd: &str) -> Port {
    let r1 = map_over(g, m, &[(x, ArgMode::Mapped)], |mb, ins| {
        let inner = map_over(&mut mb.g, d_dim, &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
            let r = mb2.g.ew1(Expr::var(0).pow(Expr::cst(2.0)), i2[0]);
            mb2.collect(r);
        });
        mb.collect(inner[0]);
    });
    let r2 = map_over(g, m, &[(r1[0], ArgMode::Mapped)], |mb, ins| {
        let inner = map_over(&mut mb.g, d_dim, &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
            let r = mb2.g.func(FuncOp::RowSum, &[i2[0]]);
            mb2.collect(r);
        });
        mb.collect(inner[0]);
    });
    let r3 = map_over(g, m, &[(r2[0], ArgMode::Mapped)], |mb, ins| {
        let red = mb.g.reduce(ReduceOp::Add, ins[0]);
        let rr = mb.g.ew1(
            Expr::var(0).div(Expr::param(dd)).sqrt().recip(),
            red,
        );
        mb.collect(rr);
    });
    let r4 = map_over(
        g,
        m,
        &[(x, ArgMode::Mapped), (r3[0], ArgMode::Mapped)],
        |mb, ins| {
            let inner = map_over(
                &mut mb.g,
                d_dim,
                &[(ins[0], ArgMode::Mapped), (ins[1], ArgMode::Bcast)],
                |mb2, i2| {
                    let r = mb2.g.func(FuncOp::RowScale, &[i2[0], i2[1]]);
                    mb2.collect(r);
                },
            );
            mb.collect(inner[0]);
        },
    );
    r4[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::programs;
    use crate::ir::validate::assert_valid;
    use crate::rules::map_ids;

    #[test]
    fn attention_initial_structure() {
        let g = lower_array(&programs::attention());
        assert_valid(&g);
        // "Each of the matrix multiplication operators becomes a single
        //  block operator while the softmax becomes four block operators in
        //  the top-level graph." + div = 7 top-level M-maps.
        assert_eq!(map_ids(&g).len(), 7);
        for id in map_ids(&g) {
            assert_eq!(g.node(id).as_map().unwrap().dim.name(), "M");
        }
    }

    #[test]
    fn layernorm_matmul_initial_structure() {
        let g = lower_array(&programs::layernorm_matmul());
        assert_valid(&g);
        assert_eq!(map_ids(&g).len(), 8); // 7 layernorm + 1 matmul
    }

    #[test]
    fn rmsnorm_ffn_initial_structure() {
        let g = lower_array(&programs::rmsnorm_ffn_swiglu());
        assert_valid(&g);
        assert_eq!(map_ids(&g).len(), 9); // 4 rms + 3 matmuls + swish + hadamard
    }

    #[test]
    fn custom_op_becomes_misc() {
        let g = lower_array(&programs::with_custom_op());
        assert_valid(&g);
        let miscs = g
            .node_ids()
            .filter(|&i| matches!(g.node(i).kind, NodeKind::Misc { .. }))
            .count();
        assert_eq!(miscs, 1);
    }

    #[test]
    fn everything_unfused_initially() {
        // Table-2 subgraphs materialize every intermediate.
        let g = lower_array(&programs::attention());
        assert!(g.interior_buffered_count_recursive() >= 6);
    }
}
