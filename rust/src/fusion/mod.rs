//! The rule-based fusion algorithm (paper §4).
//!
//! * [`fuse_no_extend`] — apply rules in the priority order
//!   `8 → 4 → 5 → 9 → 3 → 1 → 2` at one graph level until quiescent.
//! * [`bfs_fuse_no_extend`] — run it over the whole hierarchy breadth-first
//!   (top-level graph first, then inner graphs, re-enqueuing children).
//! * [`bfs_extend`] — find and apply the first Rule-6 map extension anywhere
//!   in the hierarchy (breadth-first).
//! * [`fuse`] — alternate the two, snapshotting after every quiescent state;
//!   the returned snapshots go to the selection layer, which may roll back
//!   work replication introduced by extensions.

pub mod trace;

pub use trace::{FusionTrace, TraceEvent};

use crate::ir::graph::{Graph, NodeId};
use crate::rules::{self, RuleId};
use std::collections::VecDeque;

/// The paper's priority order: companion rules first, then the fusion rules.
pub const PRIORITY: [RuleId; 7] = [
    RuleId::R8,
    RuleId::R4,
    RuleId::R5,
    RuleId::R9,
    RuleId::R3,
    RuleId::R1,
    RuleId::R2,
];

/// Resolve a hierarchical path of map node ids to the inner graph it names.
pub fn graph_at<'a>(g: &'a Graph, path: &[NodeId]) -> &'a Graph {
    match path.split_first() {
        None => g,
        Some((id, rest)) => graph_at(&g.node(*id).as_map().expect("path through non-map").inner, rest),
    }
}

pub fn graph_at_mut<'a>(g: &'a mut Graph, path: &[NodeId]) -> &'a mut Graph {
    match path.split_first() {
        None => g,
        Some((id, rest)) => graph_at_mut(
            &mut g
                .node_mut(*id)
                .as_map_mut()
                .expect("path through non-map")
                .inner,
            rest,
        ),
    }
}

/// Apply the priority rules at one graph level until none matches.
pub fn fuse_no_extend(g: &mut Graph, path: &[NodeId], trace: &mut FusionTrace) {
    'outer: loop {
        for r in PRIORITY {
            if let Some(detail) = rules::try_rule(g, r) {
                trace.record(r, path, detail);
                continue 'outer;
            }
        }
        break;
    }
}

/// Breadth-first `fuse_no_extend` over the whole hierarchy.
pub fn bfs_fuse_no_extend(g: &mut Graph, trace: &mut FusionTrace) {
    fuse_no_extend(g, &[], trace);
    let mut queue: VecDeque<Vec<NodeId>> = rules::map_ids(g)
        .into_iter()
        .map(|id| vec![id])
        .collect();
    while let Some(path) = queue.pop_front() {
        {
            let sub = graph_at_mut(g, &path);
            // the path may have been created before a parent rewrite; guard
            fuse_no_extend(sub, &path, trace);
        }
        let sub = graph_at(g, &path);
        for id in rules::map_ids(sub) {
            let mut p = path.clone();
            p.push(id);
            queue.push_back(p);
        }
    }
}

/// Find and apply the first Rule-6 extension anywhere (breadth-first).
/// Returns true if a map was extended.
pub fn bfs_extend(g: &mut Graph, trace: &mut FusionTrace) -> bool {
    if let Some(detail) = rules::rule6::try_rule6(g) {
        trace.record(RuleId::R6, &[], detail);
        return true;
    }
    let mut queue: VecDeque<Vec<NodeId>> = rules::map_ids(g)
        .into_iter()
        .map(|id| vec![id])
        .collect();
    while let Some(path) = queue.pop_front() {
        {
            let sub = graph_at_mut(g, &path);
            if let Some(detail) = rules::rule6::try_rule6(sub) {
                trace.record(RuleId::R6, &path, detail);
                return true;
            }
        }
        let sub = graph_at(g, &path);
        for id in rules::map_ids(sub) {
            let mut p = path.clone();
            p.push(id);
            queue.push_back(p);
        }
    }
    false
}

/// The result of running the full fusion algorithm on one candidate.
pub struct FusionResult {
    /// Snapshots after each quiescent `bfs_fuse_no_extend`, in order; the
    /// last is the most aggressively fused (most work replication).
    pub snapshots: Vec<Graph>,
    pub trace: FusionTrace,
}

/// The paper's `fuse(G)`: alternate quiescent fusion and map extension,
/// snapshotting between rounds, until no extension applies.
pub fn fuse(mut g: Graph) -> FusionResult {
    let mut trace = FusionTrace::new();
    bfs_fuse_no_extend(&mut g, &mut trace);
    let mut snapshots = vec![g.clone()];
    while bfs_extend(&mut g, &mut trace) {
        bfs_fuse_no_extend(&mut g, &mut trace);
        snapshots.push(g.clone());
    }
    FusionResult { snapshots, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use crate::ir::func::{FuncOp, ReduceOp};
    use crate::ir::graph::{map_over, ArgMode};
    use crate::ir::types::Ty;
    use crate::ir::validate::assert_valid;

    /// matmul + relu (the paper's §1 motivating example, in block form):
    /// fuse() must produce a single kernel with no interior buffered edges.
    #[test]
    fn fuses_matmul_relu_end_to_end() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["M"]));
        let b = g.input("BT", Ty::blocks(&["N"]));
        // C[m,n] = relu(dot(A[m], B[n])) with single-block contraction:
        let mm = map_over(
            &mut g,
            "M",
            &[(a, ArgMode::Mapped), (b, ArgMode::Bcast)],
            |mb, ins| {
                let inner = map_over(
                    &mut mb.g,
                    "N",
                    &[(ins[1], ArgMode::Mapped), (ins[0], ArgMode::Bcast)],
                    |mb2, i2| {
                        let d = mb2.g.func(FuncOp::Dot, &[i2[1], i2[0]]);
                        mb2.collect(d);
                    },
                );
                mb.collect(inner[0]);
            },
        );
        let relu = map_over(&mut g, "M", &[(mm[0], ArgMode::Mapped)], |mb, ins| {
            let inner = map_over(&mut mb.g, "N", &[(ins[0], ArgMode::Mapped)], |mb2, i2| {
                let r = mb2.g.ew1(Expr::relu(Expr::var(0)), i2[0]);
                mb2.collect(r);
            });
            mb.collect(inner[0]);
        });
        g.output("C", relu[0]);
        assert_eq!(g.interior_buffered_count_recursive(), 1);

        let res = fuse(g);
        let fused = res.snapshots.last().unwrap();
        assert_valid(fused);
        assert_eq!(fused.interior_buffered_count_recursive(), 0);
        // one M-map at top level, one N-map inside
        assert_eq!(crate::rules::map_ids(fused).len(), 1);
        assert!(res.trace.count(RuleId::R1) >= 2); // top M-fusion + inner N-fusion
    }

    #[test]
    fn snapshot_before_extension_is_kept() {
        // A program needing Rule 6 yields >= 2 snapshots: pre- and
        // post-extension.
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let vt = g.input("VT", Ty::blocks(&["L", "N"]));
        let u = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        let x = map_over(
            &mut g,
            "L",
            &[(u[0], ArgMode::Bcast), (vt, ArgMode::Mapped)],
            |mb, ins| {
                let inner = map_over(
                    &mut mb.g,
                    "N",
                    &[(ins[0], ArgMode::Mapped), (ins[1], ArgMode::Mapped)],
                    |mb2, i2| {
                        let d = mb2.g.func(FuncOp::Dot, &[i2[0], i2[1]]);
                        mb2.collect(d);
                    },
                );
                let red = mb.g.reduce(ReduceOp::Add, inner[0]);
                mb.collect(red);
            },
        );
        g.output("O", x[0]);

        let res = fuse(g);
        assert_eq!(res.snapshots.len(), 2);
        assert_eq!(res.trace.count(RuleId::R6), 1);
        // pre-extension snapshot still has the buffered edge; final doesn't
        assert_eq!(
            res.snapshots[0].interior_buffered_count_recursive(),
            1
        );
        assert_eq!(
            res.snapshots[1].interior_buffered_count_recursive(),
            0
        );
        for s in &res.snapshots {
            assert_valid(s);
        }
    }
}
