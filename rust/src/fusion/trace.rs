//! Fusion trace: a structured log of every rule application.
//!
//! The paper's §5 walks through each example step by step ("Step 7: Swap
//! Scale and Dot", …); the trace reproduces those walkthroughs and the
//! per-rule application counts that `rust/tests/paper_traces.rs` asserts.

use crate::ir::graph::NodeId;
use crate::rules::RuleId;
use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// 1-based step number (matches the paper's "Step N" numbering style).
    pub step: usize,
    pub rule: RuleId,
    /// Hierarchical path of map node ids from the root graph to the graph
    /// the rule fired in (empty = top level).
    pub path: Vec<NodeId>,
    pub detail: String,
}

impl fmt::Display for TraceEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let depth = self.path.len();
        write!(
            f,
            "step {:>3}  [depth {depth}]  {}  — {}",
            self.step,
            self.rule.name(),
            self.detail
        )
    }
}

#[derive(Clone, Debug, Default)]
pub struct FusionTrace {
    pub events: Vec<TraceEvent>,
}

impl FusionTrace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, rule: RuleId, path: &[NodeId], detail: String) {
        self.events.push(TraceEvent {
            step: self.events.len() + 1,
            rule,
            path: path.to_vec(),
            detail,
        });
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of applications of a given rule.
    pub fn count(&self, rule: RuleId) -> usize {
        self.events.iter().filter(|e| e.rule == rule).count()
    }

    /// Application counts for every rule that fired.
    pub fn counts(&self) -> BTreeMap<RuleId, usize> {
        let mut m = BTreeMap::new();
        for e in &self.events {
            *m.entry(e.rule).or_insert(0) += 1;
        }
        m
    }

    /// Compact per-rule summary, e.g. `R1×9 R3×5 R4×1 R6×1 R9×1`.
    pub fn summary(&self) -> String {
        self.counts()
            .iter()
            .map(|(r, n)| format!("R{}×{n}", r.short()))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

impl fmt::Display for FusionTrace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for e in &self.events {
            writeln!(f, "{e}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_counts() {
        let mut t = FusionTrace::new();
        t.record(RuleId::R1, &[], "a".into());
        t.record(RuleId::R1, &[3], "b".into());
        t.record(RuleId::R4, &[3], "c".into());
        assert_eq!(t.len(), 3);
        assert_eq!(t.count(RuleId::R1), 2);
        assert_eq!(t.count(RuleId::R2), 0);
        assert_eq!(t.summary(), "R1×2 R4×1");
        assert_eq!(t.events[1].step, 2);
    }
}
