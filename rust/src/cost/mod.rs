//! Static cost model over Loop IR.
//!
//! Derives, without executing, the quantities fusion optimizes on the
//! paper's abstract machine: global-memory traffic (bytes moved across the
//! global<->local boundary, weighted by loop trip counts), kernel-launch
//! count, compute work (flops — including work replicated by Rule 6), and a
//! peak local-memory estimate. The selection layer and the autotuner score
//! candidates with a weighted combination.
//!
//! The analyzer agrees exactly with the interpreter's `MemSim` on traffic
//! and launches (asserted by tests) — it is the "fast screen" of the two.

use crate::ir::dim::DimSizes;
use crate::ir::func::FuncOp;
use crate::ir::graph::Graph;
use crate::loopir::{BufId, COp, LoopIr, Stmt, VarId};
use std::collections::HashMap;

/// Item shape of a value (block grids share one item shape per buffer).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum VShape {
    Scalar,
    Vector(usize),
    Block(usize, usize),
}

impl VShape {
    pub fn bytes(&self) -> u64 {
        (match self {
            VShape::Scalar => 1,
            VShape::Vector(n) => *n,
            VShape::Block(r, c) => r * c,
        }) as u64
            * 4
    }

    pub fn elems(&self) -> u64 {
        self.bytes() / 4
    }
}

/// Input item shapes, keyed by program-input buffer name.
#[derive(Clone, Debug, Default)]
pub struct ShapeEnv {
    pub inputs: HashMap<String, VShape>,
}

impl ShapeEnv {
    /// Derive block shapes from full matrix shapes and block counts.
    pub fn from_full_shapes(
        ir: &LoopIr,
        sizes: &DimSizes,
        full: &HashMap<String, (usize, usize)>,
    ) -> ShapeEnv {
        let mut inputs = HashMap::new();
        for b in &ir.bufs {
            if !b.is_input {
                continue;
            }
            let (rows, cols) = *full
                .get(&b.name)
                .unwrap_or_else(|| panic!("no full shape for input {}", b.name));
            assert_eq!(b.dims.len(), 2, "input {} must be 2-d blocked", b.name);
            let rb = sizes.get(&b.dims[0]);
            let cb = sizes.get(&b.dims[1]);
            assert!(
                rows % rb == 0 && cols % cb == 0,
                "{}: {rows}x{cols} not divisible into {rb}x{cb} blocks",
                b.name
            );
            inputs.insert(b.name.clone(), VShape::Block(rows / rb, cols / cb));
        }
        ShapeEnv { inputs }
    }
}

/// The analysis result.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    pub loaded_bytes: u64,
    pub stored_bytes: u64,
    pub flops: u64,
    pub launches: u64,
    pub peak_local_bytes: u64,
}

impl Cost {
    pub fn traffic(&self) -> u64 {
        self.loaded_bytes + self.stored_bytes
    }
}

/// Weights combining the cost components into one scalar.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Equivalent bytes charged per kernel launch (fixed overhead).
    pub launch_overhead_bytes: f64,
    /// Bytes-equivalent per flop (how compute-bound the machine is);
    /// small = bandwidth-bound machine, traffic dominates.
    pub bytes_per_flop: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // A bandwidth-bound accelerator: 4 KiB per launch, ~100 flops per
        // byte of bandwidth.
        CostModel {
            launch_overhead_bytes: 4096.0,
            bytes_per_flop: 0.01,
        }
    }
}

impl CostModel {
    pub fn scalar(&self, c: &Cost) -> f64 {
        c.traffic() as f64
            + self.launch_overhead_bytes * c.launches as f64
            + self.bytes_per_flop * c.flops as f64
    }
}

struct Analyzer<'a> {
    sizes: &'a DimSizes,
    buf_decls: &'a [crate::loopir::BufDecl],
    buf_shapes: Vec<Option<VShape>>,
    var_shapes: HashMap<VarId, VShape>,
    cost: Cost,
}

/// Statically analyze a lowered program.
pub fn analyze(ir: &LoopIr, sizes: &DimSizes, env: &ShapeEnv) -> Cost {
    let mut a = Analyzer {
        sizes,
        buf_decls: &ir.bufs,
        buf_shapes: vec![None; ir.bufs.len()],
        var_shapes: HashMap::new(),
        cost: Cost::default(),
    };
    for (i, b) in ir.bufs.iter().enumerate() {
        if b.is_input {
            let s = env
                .inputs
                .get(&b.name)
                .unwrap_or_else(|| panic!("ShapeEnv missing input {}", b.name));
            a.buf_shapes[i] = Some(*s);
        }
    }
    a.cost.launches = ir.kernel_launches() as u64;
    let local = a.walk(&ir.body, 1, 1);
    a.cost.peak_local_bytes = local;
    a.cost
}

impl<'a> Analyzer<'a> {
    /// Walk statements with the given trip multiplier; returns the local-
    /// memory bytes live at this level (vars assigned here + deepest child).
    /// `own_trips` is the trip count of the innermost enclosing loop (1 at
    /// top level) — needed to discount the first, initializing iteration of
    /// each accumulator, which performs no addition.
    fn walk(&mut self, stmts: &[Stmt], mult: u64, own_trips: u64) -> u64 {
        let mut here: u64 = 0;
        // Sibling loops' locals all stay resident in the simulator (vars are
        // only reset by an enclosing iteration), so peak sums siblings.
        let mut children: u64 = 0;
        for s in stmts {
            match s {
                Stmt::Loop {
                    dim,
                    skip_first,
                    body,
                    ..
                } => {
                    let n = self.sizes.get(dim) as u64;
                    let trips = if *skip_first { n.saturating_sub(1) } else { n };
                    let inner = self.walk(body, mult * trips, trips);
                    children += inner;
                }
                Stmt::Load { var, buf, .. } => {
                    let sh = self.buf_shape(*buf);
                    self.var_shapes.insert(*var, sh);
                    self.cost.loaded_bytes += sh.bytes() * mult;
                    here += sh.bytes();
                }
                Stmt::Store { var, buf, .. } => {
                    let sh = self.var_shape(*var);
                    if self.buf_shapes[*buf].is_none() {
                        self.buf_shapes[*buf] = Some(sh);
                    }
                    self.cost.stored_bytes += sh.bytes() * mult;
                }
                Stmt::Compute { var, op, args } => {
                    let shapes: Vec<VShape> =
                        args.iter().map(|a| self.var_shape(*a)).collect();
                    let (sh, fl) = compute_shape(op, &shapes);
                    self.var_shapes.insert(*var, sh);
                    self.cost.flops += fl * mult;
                    here += sh.bytes();
                }
                Stmt::MiscCall { args, out, .. } => {
                    // opaque kernel: reads every input element, writes every
                    // output element, once per enclosing trip
                    for (buf, idx) in args {
                        let sh = self.buf_shape(*buf);
                        let n = self.unbound_count(*buf, idx);
                        self.cost.loaded_bytes += sh.bytes() * n * mult;
                    }
                    let (obuf, oidx) = out;
                    // output shape unknown for an opaque op: assume the
                    // first input's item shape
                    let osh = self.buf_shapes[*obuf].unwrap_or_else(|| {
                        let s = self.buf_shape(args[0].0);
                        self.buf_shapes[*obuf] = Some(s);
                        s
                    });
                    let n = self.unbound_count(*obuf, oidx);
                    self.cost.stored_bytes += osh.bytes() * n * mult;
                }
                Stmt::Accum { var, src, .. } => {
                    let sh = self.var_shape(*src);
                    if !self.var_shapes.contains_key(var) {
                        self.var_shapes.insert(*var, sh);
                        here += sh.bytes();
                    }
                    // the first iteration of the carrying loop initializes
                    // the accumulator (no addition performed)
                    self.cost.flops += sh.elems() * (mult - mult / own_trips.max(1));
                }
            }
        }
        here + children
    }

    /// Number of elements an opaque call touches: the product of the sizes
    /// of the unbound index slots.
    fn unbound_count(&self, b: BufId, idx: &[Option<crate::loopir::Index>]) -> u64 {
        idx.iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| self.sizes.get(&self.buf_decls[b].dims[i]) as u64)
            .product::<u64>()
            .max(1)
    }

    fn buf_shape(&self, b: BufId) -> VShape {
        self.buf_shapes[b].unwrap_or_else(|| panic!("buffer {b} loaded before any store"))
    }

    fn var_shape(&self, v: VarId) -> VShape {
        *self
            .var_shapes
            .get(&v)
            .unwrap_or_else(|| panic!("var t{v} used before assignment in analysis"))
    }
}

fn compute_shape(op: &COp, args: &[VShape]) -> (VShape, u64) {
    match op {
        COp::Func(f) => shape_of_func(f, args),
        COp::Misc(_) => (args[0], 0),
    }
}

/// Item-shape and flop rule for a functional operator (shared with the
/// graph-level shape inference in `select`).
pub fn shape_of_func(f: &FuncOp, args: &[VShape]) -> (VShape, u64) {
    match f {
        FuncOp::Add | FuncOp::Mul => (args[0], args[0].elems()),
        FuncOp::RowShift | FuncOp::RowScale => (args[0], args[0].elems()),
        FuncOp::RowSum => match args[0] {
            VShape::Block(r, c) => (VShape::Vector(r), (r * c) as u64),
            other => panic!("row_sum of {other:?}"),
        },
        FuncOp::Dot => match (args[0], args[1]) {
            (VShape::Block(r, k), VShape::Block(n, k2)) => {
                assert_eq!(k, k2, "dot contraction mismatch");
                (VShape::Block(r, n), 2 * (r * k * n) as u64)
            }
            other => panic!("dot of {other:?}"),
        },
        FuncOp::Outer => match (args[0], args[1]) {
            (VShape::Vector(r), VShape::Vector(n)) => (VShape::Block(r, n), (r * n) as u64),
            other => panic!("outer of {other:?}"),
        },
        FuncOp::Ew(_) => (args[0], args[0].elems()),
    }
}

/// Convenience: lower a block program and analyze it in one call.
pub fn cost_of(
    g: &Graph,
    sizes: &DimSizes,
    full: &HashMap<String, (usize, usize)>,
) -> Cost {
    let ir = crate::loopir::lower::lower(g);
    let env = ShapeEnv::from_full_shapes(&ir, sizes, full);
    analyze(&ir, sizes, &env)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::programs;
    use crate::exec::{run, Workload};
    use crate::fusion::fuse;
    use crate::lower::lower_array;
    use crate::tensor::Rng;

    fn attention_setup() -> (
        crate::ir::graph::Graph,
        DimSizes,
        HashMap<String, (usize, usize)>,
        Workload,
    ) {
        let g = lower_array(&programs::attention());
        let sizes = DimSizes::of(&[("M", 2), ("N", 3), ("D", 2), ("L", 2)]);
        let mut full = HashMap::new();
        full.insert("Q".to_string(), (8, 16));
        full.insert("KT".to_string(), (12, 16));
        full.insert("VT".to_string(), (10, 12));
        let mut rng = Rng::new(1);
        let wl = Workload::new(sizes.clone())
            .input("Q", rng.mat(8, 16))
            .input("KT", rng.mat(12, 16))
            .input("VT", rng.mat(10, 12))
            .param("DD", 16.0);
        (g, sizes, full, wl)
    }

    /// The static analyzer must agree with the interpreter's MemSim.
    #[test]
    fn static_matches_measured_unfused() {
        let (g, sizes, full, wl) = attention_setup();
        let st = cost_of(&g, &sizes, &full);
        let dy = run(&g, &wl).mem;
        assert_eq!(st.loaded_bytes, dy.loaded_bytes);
        assert_eq!(st.stored_bytes, dy.stored_bytes);
        assert_eq!(st.launches, dy.kernel_launches);
        assert_eq!(st.flops, dy.flops);
    }

    #[test]
    fn static_matches_measured_fused() {
        let (g, sizes, full, wl) = attention_setup();
        let fused = fuse(g).snapshots.pop().unwrap();
        let st = cost_of(&fused, &sizes, &full);
        let dy = run(&fused, &wl).mem;
        assert_eq!(st.loaded_bytes, dy.loaded_bytes);
        assert_eq!(st.stored_bytes, dy.stored_bytes);
        assert_eq!(st.launches, dy.kernel_launches);
        assert_eq!(st.flops, dy.flops);
    }

    #[test]
    fn fusion_reduces_scalar_cost() {
        let (g, sizes, full, _) = attention_setup();
        let model = CostModel::default();
        let before = model.scalar(&cost_of(&g, &sizes, &full));
        let fused = fuse(g).snapshots.pop().unwrap();
        let after = model.scalar(&cost_of(&fused, &sizes, &full));
        assert!(after < before, "fused {after} !< unfused {before}");
    }

    #[test]
    fn vshape_bytes() {
        assert_eq!(VShape::Scalar.bytes(), 4);
        assert_eq!(VShape::Vector(8).bytes(), 32);
        assert_eq!(VShape::Block(4, 8).bytes(), 128);
    }
}
