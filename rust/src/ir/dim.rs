//! Iteration dimensions of a block program.
//!
//! A [`Dim`] names one blocking dimension of the program (the paper's `M`,
//! `N`, `K`, `D`, `L`, …). The *number of blocks* along each dimension is a
//! parameter chosen after fusion by the autotuner (§2.1: "The number of
//! blocks along each dimension is a parameter, which can later be optimized
//! using an auto-tuning procedure"), so the IR only carries names; concrete
//! trip counts live in a [`DimSizes`] environment supplied at
//! execution/costing time.

use std::collections::BTreeMap;
use std::fmt;

/// A named iteration dimension (e.g. `M`, `N`, `K`).
#[derive(Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Dim(pub String);

impl Dim {
    pub fn new(name: impl Into<String>) -> Self {
        Dim(name.into())
    }
    pub fn name(&self) -> &str {
        &self.0
    }
}

impl fmt::Display for Dim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Dim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Dim({})", self.0)
    }
}

impl From<&str> for Dim {
    fn from(s: &str) -> Self {
        Dim(s.to_string())
    }
}

impl From<String> for Dim {
    fn from(s: String) -> Self {
        Dim(s)
    }
}

/// Concrete trip counts (number of blocks) per dimension.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DimSizes(pub BTreeMap<Dim, usize>);

impl DimSizes {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn of(pairs: &[(&str, usize)]) -> Self {
        let mut m = BTreeMap::new();
        for (d, n) in pairs {
            m.insert(Dim::new(*d), *n);
        }
        DimSizes(m)
    }

    pub fn get(&self, d: &Dim) -> usize {
        *self
            .0
            .get(d)
            .unwrap_or_else(|| panic!("DimSizes: missing size for dimension {d}"))
    }

    pub fn try_get(&self, d: &Dim) -> Option<usize> {
        self.0.get(d).copied()
    }

    pub fn set(&mut self, d: impl Into<Dim>, n: usize) {
        self.0.insert(d.into(), n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dim_display_and_eq() {
        let m = Dim::new("M");
        assert_eq!(m.to_string(), "M");
        assert_eq!(m, Dim::from("M"));
        assert_ne!(m, Dim::from("N"));
    }

    #[test]
    fn dim_sizes_lookup() {
        let s = DimSizes::of(&[("M", 4), ("N", 8)]);
        assert_eq!(s.get(&Dim::new("M")), 4);
        assert_eq!(s.get(&Dim::new("N")), 8);
        assert_eq!(s.try_get(&Dim::new("K")), None);
    }

    #[test]
    #[should_panic(expected = "missing size")]
    fn dim_sizes_missing_panics() {
        DimSizes::new().get(&Dim::new("Q"));
    }
}
