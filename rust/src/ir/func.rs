//! Functional operators — the paper's Table 1.
//!
//! Functional operators are stateless functions whose inputs and outputs are
//! blocks, vectors, or scalars in local memory. Each carries a set of shape
//! constraints (checked at execution time by `tensor`/`exec`) and an item
//! typing rule (checked structurally by `ir::validate`).
//!
//! One deliberate deviation from the paper's Table 1, documented in
//! DESIGN.md: the table's numpy line for `row_sum` (`sum(a, axis=0)`)
//! contradicts the constraint its own examples need. Examples 2 and 3 feed
//! `row_sum` outputs into `row_scale`/`row_shift` (which require a vector of
//! length `a.shape[0]` — one entry per *row*), so `row_sum` here sums each
//! row: `r = sum(a, axis=1)`, `r.size == a.shape[0]`.

use super::expr::Expr;
use super::types::Item;
use std::fmt;

/// Reduction operation for reduction operators and reduced map outputs.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum ReduceOp {
    /// Elementwise addition (the circled-plus of the paper).
    Add,
    /// Elementwise maximum (used by the numerical-safety pass).
    Max,
}

impl fmt::Display for ReduceOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReduceOp::Add => f.write_str("+"),
            ReduceOp::Max => f.write_str("max"),
        }
    }
}

/// A functional (block-level) operator.
#[derive(Clone, PartialEq, Debug)]
pub enum FuncOp {
    /// `r = a + b` — blocks or vectors of identical shape.
    Add,
    /// `r = a * b` — elementwise (Hadamard) product, identical shapes.
    Mul,
    /// `r = a + c[:,newaxis]` — add a value to each row of a block.
    RowShift,
    /// `r = a * c[:,newaxis]` — scale each row of a block.
    RowScale,
    /// `r[i] = sum_j a[i,j]` — sum the values in each row of a block.
    RowSum,
    /// `r = a @ b.T` — multiply a block with the transpose of another block.
    Dot,
    /// `r = outer(a, b)` — outer product of two vectors.
    Outer,
    /// An n-ary elementwise scalar function applied pointwise; all inputs
    /// share one item type, which is also the output type.
    Ew(Expr),
}

impl FuncOp {
    pub fn ew(expr: Expr) -> FuncOp {
        FuncOp::Ew(expr)
    }

    /// Number of input ports.
    pub fn arity(&self) -> usize {
        match self {
            FuncOp::Add | FuncOp::Mul | FuncOp::RowShift | FuncOp::RowScale => 2,
            FuncOp::RowSum => 1,
            FuncOp::Dot | FuncOp::Outer => 2,
            FuncOp::Ew(e) => e.arity(),
        }
    }

    /// Output item type given input item types; `None` if the inputs violate
    /// the operator's typing rule.
    pub fn out_item(&self, ins: &[Item]) -> Option<Item> {
        use Item::*;
        match self {
            FuncOp::Add | FuncOp::Mul => match ins {
                [a, b] if a == b && *a != Scalar => Some(*a),
                [Scalar, Scalar] => Some(Scalar),
                _ => None,
            },
            FuncOp::RowShift | FuncOp::RowScale => match ins {
                [Block, Vector] => Some(Block),
                _ => None,
            },
            FuncOp::RowSum => match ins {
                [Block] => Some(Vector),
                _ => None,
            },
            FuncOp::Dot => match ins {
                [Block, Block] => Some(Block),
                _ => None,
            },
            FuncOp::Outer => match ins {
                [Vector, Vector] => Some(Block),
                _ => None,
            },
            FuncOp::Ew(e) => {
                if ins.len() != e.arity().max(1).min(ins.len().max(1)) && ins.len() != e.arity() {
                    return None;
                }
                let first = *ins.first()?;
                if ins.iter().all(|i| *i == first) {
                    Some(first)
                } else {
                    None
                }
            }
        }
    }

    /// Is this an elementwise operator (Rule 9 candidate)?
    pub fn is_ew(&self) -> bool {
        matches!(self, FuncOp::Ew(_))
    }

    /// Short operator name for diagrams and listings.
    pub fn name(&self) -> &'static str {
        match self {
            FuncOp::Add => "add",
            FuncOp::Mul => "mul",
            FuncOp::RowShift => "row_shift",
            FuncOp::RowScale => "row_scale",
            FuncOp::RowSum => "row_sum",
            FuncOp::Dot => "dot",
            FuncOp::Outer => "outer",
            FuncOp::Ew(_) => "ew",
        }
    }
}

impl fmt::Display for FuncOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FuncOp::Ew(e) => write!(f, "ew({e})"),
            other => f.write_str(other.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use Item::*;

    #[test]
    fn arities() {
        assert_eq!(FuncOp::Add.arity(), 2);
        assert_eq!(FuncOp::RowSum.arity(), 1);
        assert_eq!(FuncOp::ew(Expr::var(0).exp()).arity(), 1);
        assert_eq!(
            FuncOp::ew(Expr::var(0).add(Expr::var(1))).arity(),
            2
        );
    }

    #[test]
    fn typing_rules() {
        assert_eq!(FuncOp::Add.out_item(&[Block, Block]), Some(Block));
        assert_eq!(FuncOp::Add.out_item(&[Block, Vector]), None);
        assert_eq!(FuncOp::RowScale.out_item(&[Block, Vector]), Some(Block));
        assert_eq!(FuncOp::RowScale.out_item(&[Vector, Block]), None);
        assert_eq!(FuncOp::RowSum.out_item(&[Block]), Some(Vector));
        assert_eq!(FuncOp::Dot.out_item(&[Block, Block]), Some(Block));
        assert_eq!(FuncOp::Outer.out_item(&[Vector, Vector]), Some(Block));
        let e = FuncOp::ew(Expr::var(0).exp());
        assert_eq!(e.out_item(&[Vector]), Some(Vector));
        assert_eq!(e.out_item(&[Scalar]), Some(Scalar));
    }

    #[test]
    fn ew_mixed_items_rejected() {
        let e = FuncOp::ew(Expr::var(0).add(Expr::var(1)));
        assert_eq!(e.out_item(&[Block, Vector]), None);
        assert_eq!(e.out_item(&[Vector, Vector]), Some(Vector));
    }

    #[test]
    fn display() {
        assert_eq!(FuncOp::Dot.to_string(), "dot");
        assert_eq!(
            FuncOp::ew(Expr::var(0).exp()).to_string(),
            "ew(exp(x0))"
        );
    }
}
