//! Value types of block-program edges.
//!
//! §2.1 of the paper distinguishes values that fit in a processor's local
//! memory — an individual *block*, *vector* or *scalar* — from values that
//! must live in global memory: a *list of blocks*, *list of vectors*, or
//! *list of lists*. We encode both in one type: [`Ty`] is an [`Item`] wrapped
//! in zero or more levels of list nesting, each level tagged with the
//! iteration [`Dim`] that indexes it (outermost first).
//!
//! An edge whose type has a non-empty `dims` is a **buffered** edge (red in
//! the paper's diagrams): its value is materialized in a global-memory
//! buffer. An edge with empty `dims` is **unbuffered**: the value is produced
//! and consumed in local memory on the same processor. Edges incident to
//! program inputs/outputs are buffered regardless (program I/O resides in
//! global memory).

use super::dim::Dim;
use std::fmt;

/// What a single local-memory value is: a scalar, a (column) vector, or a
/// 2-D block.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Item {
    Scalar,
    Vector,
    Block,
}

impl fmt::Display for Item {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Item::Scalar => f.write_str("scalar"),
            Item::Vector => f.write_str("vector"),
            Item::Block => f.write_str("block"),
        }
    }
}

/// The type of a block-program value: an item nested in `dims.len()` levels
/// of lists. `dims` is ordered outermost-first, matching the index order of
/// the paper's listings (`I1[m,n]` has `dims = [M, N]`).
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Ty {
    pub item: Item,
    pub dims: Vec<Dim>,
}

impl Ty {
    pub fn new(item: Item, dims: Vec<Dim>) -> Self {
        Ty { item, dims }
    }

    /// A bare local-memory item (unbuffered when it flows between operators).
    pub fn item(item: Item) -> Self {
        Ty { item, dims: vec![] }
    }

    pub fn scalar() -> Self {
        Ty::item(Item::Scalar)
    }
    pub fn vector() -> Self {
        Ty::item(Item::Vector)
    }
    pub fn block() -> Self {
        Ty::item(Item::Block)
    }

    /// A list-of-…-of-`item` over the given dims (outermost first).
    pub fn list(item: Item, dims: &[&str]) -> Self {
        Ty {
            item,
            dims: dims.iter().map(|d| Dim::new(*d)).collect(),
        }
    }

    /// Blocks split along the given dims, e.g. `Ty::blocks(&["M","N"])` for a
    /// matrix blocked along both dimensions.
    pub fn blocks(dims: &[&str]) -> Self {
        Ty::list(Item::Block, dims)
    }

    /// True iff the value is a list (needs a global-memory buffer).
    pub fn is_list(&self) -> bool {
        !self.dims.is_empty()
    }

    /// Does the list nesting mention `d` anywhere?
    pub fn has_dim(&self, d: &Dim) -> bool {
        self.dims.contains(d)
    }

    /// Type of one element after a map over `d` strips the first occurrence
    /// of `d` from the nesting. Panics if `d` is absent.
    pub fn strip(&self, d: &Dim) -> Ty {
        let pos = self
            .dims
            .iter()
            .position(|x| x == d)
            .unwrap_or_else(|| panic!("Ty::strip: dim {d} not in {self}"));
        let mut dims = self.dims.clone();
        dims.remove(pos);
        Ty {
            item: self.item,
            dims,
        }
    }

    /// Type of the collected output of a map over `d`: prepend `d`.
    pub fn collect(&self, d: &Dim) -> Ty {
        let mut dims = Vec::with_capacity(self.dims.len() + 1);
        dims.push(d.clone());
        dims.extend(self.dims.iter().cloned());
        Ty {
            item: self.item,
            dims,
        }
    }

    /// Type after reducing the outermost list level. Panics on a non-list.
    pub fn reduce(&self) -> Ty {
        assert!(
            self.is_list(),
            "Ty::reduce: cannot reduce non-list type {self}"
        );
        Ty {
            item: self.item,
            dims: self.dims[1..].to_vec(),
        }
    }
}

impl fmt::Display for Ty {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.dims.is_empty() {
            write!(f, "{}", self.item)
        } else {
            write!(f, "[")?;
            for (i, d) in self.dims.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write!(f, "{d}")?;
            }
            write!(f, "]{}", self.item)
        }
    }
}

impl fmt::Debug for Ty {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_and_collect_roundtrip() {
        let t = Ty::blocks(&["M", "N"]);
        let m = Dim::new("M");
        let s = t.strip(&m);
        assert_eq!(s, Ty::blocks(&["N"]));
        assert_eq!(s.collect(&m), t);
    }

    #[test]
    fn strip_first_occurrence_mid_list() {
        // I2[k,n] consumed by a map over N strips the inner dim.
        let t = Ty::blocks(&["K", "N"]);
        assert_eq!(t.strip(&Dim::new("N")), Ty::blocks(&["K"]));
    }

    #[test]
    fn reduce_strips_outer() {
        let t = Ty::list(Item::Vector, &["K"]);
        assert_eq!(t.reduce(), Ty::vector());
    }

    #[test]
    fn buffered_is_list() {
        assert!(Ty::blocks(&["M"]).is_list());
        assert!(!Ty::block().is_list());
    }

    #[test]
    fn display_forms() {
        assert_eq!(Ty::blocks(&["M", "N"]).to_string(), "[M,N]block");
        assert_eq!(Ty::scalar().to_string(), "scalar");
    }

    #[test]
    #[should_panic(expected = "not in")]
    fn strip_missing_dim_panics() {
        Ty::blocks(&["M"]).strip(&Dim::new("Z"));
    }
}
