//! Batched (block-at-a-time) elementwise expression VM.
//!
//! [`super::expr::CompiledExpr`] evaluates one element per call through a
//! postfix stack machine — fine for scalars, ruinous for blocks: a fused
//! mega-kernel's elementwise tail re-runs the interpreter dispatch loop
//! `rows*cols` times per block operator ("the largest remaining scalar
//! hotspot" per ROADMAP). This module compiles the postfix tape **once**
//! into a vector program whose ops operate on whole slices:
//!
//! * the per-element value stack becomes a **register stack of slabs** —
//!   one flat scratch buffer ([`EwScratch`]'s slab store) striped into
//!   `max_slabs` strides of up to [`SLAB_CHUNK`] elements, reused across
//!   calls (no per-element `Vec` churn, bounded footprint for big blocks);
//! * `PushVar`/`PushConst` fill a slab (one `copy_from_slice`/`fill`);
//!   `Un`/`Bin` run one [`crate::tensor::simd`] elementwise slice kernel
//!   over the top slab(s);
//! * the translation fuses `PushVar x; Bin op` / `PushConst c; Bin op`
//!   pairs into single `BinVar`/`BinConst` vector ops — in
//!   postfix, an operand pushed immediately before a binary op *is* that
//!   op's right-hand side, so the fusion just skips materializing it in a
//!   slab (most binary ops in real programs have a leaf rhs, so this
//!   halves slab traffic and stack depth).
//!
//! **Bit-identity contract.** For every element, the VM applies exactly
//! the operation sequence `eval_with` applies, with the same operand
//! order, through kernels that are per-element identical to the scalar
//! ops on every dispatch path (see `tensor::simd`'s elementwise kernel
//! docs: AVX2 only where IEEE-identical; libm and `f32::max`/`min` stay
//! scalar calls inside slice loops). Elementwise ops carry no
//! cross-element reduction, so chunking into slabs cannot reorder
//! anything; the remainder tail of a chunk runs the identical op
//! sequence. The differential fuzz suite (`tests/expr_fuzz.rs`) holds
//! the VM to bitwise equality with `eval_with` — NaN payloads included —
//! across simd on/off.

use super::expr::{BinOp, CompiledExpr, TapeOp, UnOp};
use crate::tensor::simd;

/// Elements per slab stride: bounds scratch memory at
/// `max_slabs * SLAB_CHUNK` floats however large the block is, while
/// keeping the working set of one chunk L1/L2-resident.
pub const SLAB_CHUNK: usize = 512;

/// One op of the vector program. `Bin*` ops combine **into** the slab
/// below the operand (lhs in place), mirroring `eval_with`'s
/// `*x = *x op y`.
#[derive(Clone, Copy, Debug)]
enum VmOp {
    /// Copy input `i` into a fresh top slab.
    PushVar(usize),
    /// Fill a fresh top slab with a constant.
    PushConst(f32),
    /// Unary kernel in place on the top slab.
    Un(UnOp),
    /// Binary kernel: `top-1 = (top-1) op top`; pops.
    Bin(BinOp),
    /// Fused `PushVar i; Bin op`: `top = top op input[i]`.
    BinVar(BinOp, usize),
    /// Fused `PushConst c; Bin op`: `top = top op c`.
    BinConst(BinOp, f32),
}

/// A compiled-once vector program over slices (see module docs).
#[derive(Clone, Debug)]
pub struct ExprVm {
    ops: Vec<VmOp>,
    /// Peak register-stack depth of the fused program (≤ the scalar
    /// tape's `max_stack`).
    max_slabs: usize,
    /// Input arity (same meaning as [`CompiledExpr::arity`]).
    pub arity: usize,
}

/// Reusable scratch for elementwise evaluation: the scalar stack machine's
/// value stack plus the VM's slab file. One per execution thread
/// (`exec::engine::Machine` owns one; the interpreter builds one per
/// compute site, its deliberate naive-baseline behavior).
#[derive(Default)]
pub struct EwScratch {
    /// Scalar-path stack for [`CompiledExpr::eval_with`].
    pub stack: Vec<f32>,
    /// Slab file, striped `max_slabs × stride`; grown on demand, reused.
    slabs: Vec<f32>,
}

impl EwScratch {
    pub fn new() -> EwScratch {
        EwScratch {
            stack: Vec::with_capacity(16),
            slabs: Vec::new(),
        }
    }
}

impl ExprVm {
    /// Translate a compiled postfix tape into the fused vector program.
    pub fn from_compiled(ce: &CompiledExpr) -> ExprVm {
        let tape = ce.ops();
        let mut ops = Vec::with_capacity(tape.len());
        let mut i = 0;
        while i < tape.len() {
            // In postfix, a leaf pushed immediately before a binary op is
            // that op's rhs — fuse the pair.
            match (&tape[i], tape.get(i + 1)) {
                (TapeOp::PushVar(v), Some(TapeOp::Bin(b))) => {
                    ops.push(VmOp::BinVar(*b, *v));
                    i += 2;
                }
                (TapeOp::PushConst(c), Some(TapeOp::Bin(b))) => {
                    ops.push(VmOp::BinConst(*b, *c));
                    i += 2;
                }
                (TapeOp::PushVar(v), _) => {
                    ops.push(VmOp::PushVar(*v));
                    i += 1;
                }
                (TapeOp::PushConst(c), _) => {
                    ops.push(VmOp::PushConst(*c));
                    i += 1;
                }
                (TapeOp::Un(u), _) => {
                    ops.push(VmOp::Un(*u));
                    i += 1;
                }
                (TapeOp::Bin(b), _) => {
                    ops.push(VmOp::Bin(*b));
                    i += 1;
                }
            }
        }
        let mut depth = 0usize;
        let mut max = 0usize;
        for op in &ops {
            match op {
                VmOp::PushVar(_) | VmOp::PushConst(_) => depth += 1,
                VmOp::Bin(_) => depth -= 1,
                VmOp::Un(_) | VmOp::BinVar(..) | VmOp::BinConst(..) => {}
            }
            max = max.max(depth);
        }
        ExprVm {
            ops,
            max_slabs: max,
            arity: ce.arity,
        }
    }

    /// Evaluate the expression over whole slices: `out[e] =
    /// expr(args[0][e], …, args[arity-1][e])` for every `e`, bit-identical
    /// to calling [`CompiledExpr::eval_with`] per element. `args` must
    /// hold `arity` slices, each of `out.len()` elements (arity 0 needs
    /// no inputs and fills `out` with the constant result).
    pub fn run(&self, args: &[&[f32]], out: &mut [f32], scratch: &mut EwScratch) {
        assert_eq!(args.len(), self.arity, "exprvm: arity mismatch");
        for a in args {
            assert_eq!(a.len(), out.len(), "exprvm: input length mismatch");
        }
        let len = out.len();
        if len == 0 {
            return;
        }
        let stride = len.min(SLAB_CHUNK);
        let want = self.max_slabs.max(1) * stride;
        if scratch.slabs.len() < want {
            scratch.slabs.resize(want, 0.0);
        }
        let mut base = 0;
        while base < len {
            let n = stride.min(len - base);
            self.run_chunk(args, base, n, stride, &mut scratch.slabs, out);
            base += n;
        }
    }

    /// One slab-sized chunk `[base, base+n)` of the element range.
    fn run_chunk(
        &self,
        args: &[&[f32]],
        base: usize,
        n: usize,
        stride: usize,
        slabs: &mut [f32],
        out: &mut [f32],
    ) {
        let mut depth = 0usize;
        for op in &self.ops {
            match op {
                VmOp::PushVar(i) => {
                    slabs[depth * stride..depth * stride + n]
                        .copy_from_slice(&args[*i][base..base + n]);
                    depth += 1;
                }
                VmOp::PushConst(c) => {
                    slabs[depth * stride..depth * stride + n].fill(*c);
                    depth += 1;
                }
                VmOp::Un(u) => {
                    let top = &mut slabs[(depth - 1) * stride..(depth - 1) * stride + n];
                    apply_un(*u, top);
                }
                VmOp::Bin(b) => {
                    let (lo, hi) = slabs.split_at_mut((depth - 1) * stride);
                    let lhs = &mut lo[(depth - 2) * stride..(depth - 2) * stride + n];
                    let rhs = &hi[..n];
                    apply_bin(*b, lhs, rhs);
                    depth -= 1;
                }
                VmOp::BinVar(b, i) => {
                    let lhs = &mut slabs[(depth - 1) * stride..(depth - 1) * stride + n];
                    apply_bin(*b, lhs, &args[*i][base..base + n]);
                }
                VmOp::BinConst(b, c) => {
                    let lhs = &mut slabs[(depth - 1) * stride..(depth - 1) * stride + n];
                    apply_bin_c(*b, lhs, *c);
                }
            }
        }
        out[base..base + n].copy_from_slice(&slabs[..n]);
    }
}

/// Unary slice kernel dispatch — per element exactly `eval_with`'s match.
fn apply_un(u: UnOp, x: &mut [f32]) {
    match u {
        UnOp::Neg => simd::ew_neg(x),
        UnOp::Exp => simd::ew_exp(x),
        UnOp::Log => simd::ew_ln(x),
        UnOp::Sqrt => simd::ew_sqrt(x),
        UnOp::Recip => simd::ew_recip(x),
        UnOp::Abs => simd::ew_abs(x),
    }
}

/// Binary slice kernel dispatch (`lhs = lhs op rhs`, operand order as in
/// `eval_with`'s `*x = *x op y`).
fn apply_bin(b: BinOp, lhs: &mut [f32], rhs: &[f32]) {
    match b {
        BinOp::Add => simd::add_assign(lhs, rhs),
        BinOp::Sub => simd::ew_sub(lhs, rhs),
        BinOp::Mul => simd::mul_assign(lhs, rhs),
        BinOp::Div => simd::ew_div(lhs, rhs),
        BinOp::Pow => simd::ew_pow(lhs, rhs),
        BinOp::Max => simd::ew_max(lhs, rhs),
        BinOp::Min => simd::ew_min(lhs, rhs),
    }
}

/// Binary slice kernel with a constant rhs.
fn apply_bin_c(b: BinOp, lhs: &mut [f32], c: f32) {
    match b {
        BinOp::Add => simd::add_scalar(lhs, c),
        BinOp::Sub => simd::ew_sub_c(lhs, c),
        BinOp::Mul => simd::mul_scalar(lhs, c),
        BinOp::Div => simd::ew_div_c(lhs, c),
        BinOp::Pow => simd::ew_pow_c(lhs, c),
        BinOp::Max => simd::ew_max_c(lhs, c),
        BinOp::Min => simd::ew_min_c(lhs, c),
    }
}

/// A pre-compiled elementwise kernel: the scalar tape (kept for the
/// per-scalar path and as the differential-fuzz reference) plus its
/// batched vector program. This is what `ComputeKind::Ew` carries.
#[derive(Clone, Debug)]
pub struct EwKernel {
    pub expr: CompiledExpr,
    pub vm: ExprVm,
}

impl EwKernel {
    pub fn new(expr: CompiledExpr) -> EwKernel {
        let vm = ExprVm::from_compiled(&expr);
        EwKernel { expr, vm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use std::collections::BTreeMap;

    fn no_params() -> BTreeMap<String, f32> {
        BTreeMap::new()
    }

    fn assert_vm_matches_scalar(e: &Expr, args: &[Vec<f32>], len: usize) {
        let ce = e.compile(&no_params());
        let vm = ExprVm::from_compiled(&ce);
        let mut scratch = EwScratch::new();
        let slices: Vec<&[f32]> = args.iter().map(|a| &a[..]).collect();
        let mut got = vec![0.0f32; len];
        vm.run(&slices, &mut got, &mut scratch);
        let mut xs = vec![0.0f32; ce.arity];
        for e_i in 0..len {
            for (k, a) in args.iter().enumerate() {
                xs[k] = a[e_i];
            }
            let want = ce.eval_with(&xs, &mut scratch.stack);
            assert_eq!(
                got[e_i].to_bits(),
                want.to_bits(),
                "element {e_i}: vm {} vs scalar {want}",
                got[e_i]
            );
        }
    }

    #[test]
    fn swish_batched_matches_scalar() {
        let e = Expr::swish(Expr::var(0));
        let xs: Vec<f32> = (0..37).map(|i| (i as f32 - 18.0) * 0.3).collect();
        assert_vm_matches_scalar(&e, &[xs], 37);
    }

    #[test]
    fn fusion_preserves_operand_order() {
        // c - x and x - c must not be confused by the BinConst fusion
        let x: Vec<f32> = vec![1.0, 2.5, -3.0, f32::NAN, 0.0];
        let a = Expr::cst(10.0).sub(Expr::var(0)); // PushConst; PushVar; Bin
        let b = Expr::var(0).sub(Expr::cst(10.0)); // PushVar; BinConst fused
        assert_vm_matches_scalar(&a, &[x.clone()], 5);
        assert_vm_matches_scalar(&b, &[x], 5);
    }

    #[test]
    fn arity_zero_fills_constant() {
        let e = Expr::cst(2.0).mul(Expr::cst(3.0));
        let ce = e.compile(&no_params());
        let vm = ExprVm::from_compiled(&ce);
        let mut out = vec![0.0f32; 11];
        vm.run(&[], &mut out, &mut EwScratch::new());
        assert!(out.iter().all(|&v| v == 6.0));
    }

    #[test]
    fn chunking_crosses_slab_boundary() {
        // length > SLAB_CHUNK exercises the multi-chunk path
        let len = SLAB_CHUNK + 129;
        let x: Vec<f32> = (0..len).map(|i| (i as f32 * 0.37).sin()).collect();
        let y: Vec<f32> = (0..len).map(|i| (i as f32 * 0.11).cos()).collect();
        let e = Expr::var(0)
            .mul(Expr::var(1))
            .add(Expr::var(0).neg().exp())
            .max(Expr::var(1).abs().sqrt());
        assert_vm_matches_scalar(&e, &[x, y], len);
    }

    #[test]
    fn deep_stack_uses_plain_bins() {
        // right-leaning tree defeats rhs fusion, forcing real slab pops
        let e = Expr::var(0).add(
            Expr::var(1)
                .exp()
                .add(Expr::var(0).mul(Expr::var(1).add(Expr::var(0).recip()))),
        );
        let x: Vec<f32> = (0..19).map(|i| i as f32 - 9.0).collect();
        let y: Vec<f32> = (0..19).map(|i| (i as f32).ln().max(0.1)).collect();
        assert_vm_matches_scalar(&e, &[x, y], 19);
    }
}
