//! Human-readable rendering of block programs.
//!
//! Two renderers: an indented hierarchical text dump (for debugging and the
//! fusion trace), and a Graphviz `dot` exporter that colors buffered edges
//! red like the paper's figures. The paper-style *code listings* live in
//! `loopir::print` (they require lowering).

use super::graph::{port, ArgMode, Graph, NodeKind, OutMode};
use std::fmt::Write;

/// Indented text dump of the whole hierarchy.
pub fn dump(g: &Graph) -> String {
    let mut s = String::new();
    dump_level(g, 0, &mut s);
    s
}

fn dump_level(g: &Graph, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    for id in g.topo_order() {
        let n = g.node(id);
        match &n.kind {
            NodeKind::Input { ty } => {
                let _ = writeln!(out, "{pad}n{id} input {} : {ty}", n.label);
            }
            NodeKind::Output => {
                let src = g
                    .producer(port(id, 0))
                    .map(|p| format!("n{}.{}", p.node, p.port))
                    .unwrap_or_else(|| "?".into());
                let _ = writeln!(out, "{pad}n{id} output {} <- {src}", n.label);
            }
            NodeKind::Func(f) => {
                let args = fmt_args(g, id, f.arity());
                let _ = writeln!(out, "{pad}n{id} {f}({args})");
            }
            NodeKind::Reduce(op) => {
                let args = fmt_args(g, id, 1);
                let _ = writeln!(out, "{pad}n{id} reduce[{op}]({args})");
            }
            NodeKind::Head => {
                let args = fmt_args(g, id, 1);
                let _ = writeln!(out, "{pad}n{id} head({args})");
            }
            NodeKind::Concat { dim } => {
                let args = fmt_args(g, id, 2);
                let _ = writeln!(out, "{pad}n{id} concat[{dim}]({args})");
            }
            NodeKind::Misc { tag, .. } => {
                let _ = writeln!(out, "{pad}n{id} misc[{tag}]");
            }
            NodeKind::Map(m) => {
                let ins: Vec<String> = m
                    .inputs
                    .iter()
                    .enumerate()
                    .map(|(i, mi)| {
                        let src = g
                            .producer(port(id, i))
                            .map(|p| format!("n{}.{}", p.node, p.port))
                            .unwrap_or_else(|| "?".into());
                        let mode = match mi.mode {
                            ArgMode::Mapped => "mapped",
                            ArgMode::Bcast => "bcast",
                        };
                        format!("{src}:{mode}")
                    })
                    .collect();
                let outs: Vec<String> = m
                    .outputs
                    .iter()
                    .map(|mo| match &mo.mode {
                        OutMode::Collect => "collect".to_string(),
                        OutMode::Reduce(op) => format!("reduce[{op}]"),
                    })
                    .collect();
                let range = if m.skip_first { " range=1.." } else { "" };
                let _ = writeln!(
                    out,
                    "{pad}n{id} map {}{range} in=[{}] out=[{}]:",
                    m.dim,
                    ins.join(", "),
                    outs.join(", ")
                );
                dump_level(&m.inner, indent + 1, out);
            }
        }
    }
}

fn fmt_args(g: &Graph, id: usize, arity: usize) -> String {
    (0..arity)
        .map(|i| {
            g.producer(port(id, i))
                .map(|p| format!("n{}.{}", p.node, p.port))
                .unwrap_or_else(|| "?".into())
        })
        .collect::<Vec<_>>()
        .join(", ")
}

/// Graphviz export; buffered edges red (like the paper's diagrams), maps as
/// dashed clusters.
pub fn to_dot(g: &Graph, name: &str) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "digraph \"{name}\" {{");
    let _ = writeln!(s, "  rankdir=LR; node [fontsize=10, shape=box];");
    let mut next_cluster = 0usize;
    dot_level(g, "r", &mut s, &mut next_cluster);
    let _ = writeln!(s, "}}");
    s
}

fn dot_node_name(prefix: &str, id: usize) -> String {
    format!("\"{prefix}_n{id}\"")
}

fn dot_level(g: &Graph, prefix: &str, out: &mut String, next_cluster: &mut usize) {
    for id in g.node_ids() {
        let n = g.node(id);
        let nm = dot_node_name(prefix, id);
        match &n.kind {
            NodeKind::Input { ty } => {
                let _ = writeln!(
                    out,
                    "  {nm} [label=\"{} : {ty}\", shape=ellipse];",
                    n.label
                );
            }
            NodeKind::Output => {
                let _ = writeln!(out, "  {nm} [label=\"{}\", shape=ellipse];", n.label);
            }
            NodeKind::Func(f) => {
                let _ = writeln!(out, "  {nm} [label=\"{f}\"];");
            }
            NodeKind::Reduce(op) => {
                let _ = writeln!(out, "  {nm} [label=\"({op})\", shape=circle];");
            }
            NodeKind::Head => {
                let _ = writeln!(out, "  {nm} [label=\"head\"];");
            }
            NodeKind::Concat { dim } => {
                let _ = writeln!(out, "  {nm} [label=\"concat {dim}\"];");
            }
            NodeKind::Misc { tag, .. } => {
                let _ = writeln!(out, "  {nm} [label=\"misc:{tag}\", shape=octagon];");
            }
            NodeKind::Map(m) => {
                let cid = *next_cluster;
                *next_cluster += 1;
                let _ = writeln!(out, "  subgraph cluster_{cid} {{");
                let _ = writeln!(
                    out,
                    "    label=\"map {}\"; style=dashed; fontsize=10;",
                    m.dim
                );
                let inner_prefix = format!("{prefix}_m{id}");
                dot_level(&m.inner, &inner_prefix, out, next_cluster);
                // anchor node so outer edges have a target
                let _ = writeln!(
                    out,
                    "    {nm} [label=\"map {}\", shape=point];",
                    m.dim
                );
                let _ = writeln!(out, "  }}");
            }
        }
    }
    for e in g.edges() {
        let ty = g.out_ty(e.src);
        let buffered = ty.is_list()
            || g.node(e.src.node).is_io()
            || g.node(e.dst.node).is_io();
        let color = if buffered { "red" } else { "black" };
        let _ = writeln!(
            out,
            "  {} -> {} [color={color}, label=\"{ty}\", fontsize=8];",
            dot_node_name(prefix, e.src.node),
            dot_node_name(prefix, e.dst.node)
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use crate::ir::graph::{map_over, ArgMode, Graph};
    use crate::ir::types::Ty;

    fn sample() -> Graph {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        g.output("B", o[0]);
        g
    }

    #[test]
    fn dump_contains_structure() {
        let s = dump(&sample());
        assert!(s.contains("input A"));
        assert!(s.contains("map N"));
        assert!(s.contains("ew(exp(x0))"));
        assert!(s.contains("output B"));
    }

    #[test]
    fn dot_marks_buffered_red() {
        let d = to_dot(&sample(), "t");
        assert!(d.contains("digraph"));
        assert!(d.contains("color=red"));
        assert!(d.contains("cluster_0"));
    }
}
