//! Structural validation of block programs.
//!
//! Every rule application must preserve these invariants (the property tests
//! in `rust/tests/` re-check them after every rewrite):
//!
//! 1. the graph (at every level) is acyclic;
//! 2. every non-input port is connected, with arities respected;
//! 3. types check: functional operators consume items with the right item
//!    kinds, maps strip/collect their dimension consistently, reductions
//!    consume single-level lists;
//! 4. map port bindings reference real inner Input/Output nodes of the right
//!    shape, and inner Input types match the outer value element types.

use super::graph::{port, Graph, NodeKind};
use super::types::Ty;
use std::fmt;

#[derive(Debug, Clone)]
pub struct ValidationError {
    /// Hierarchical path of map node ids from the root, then a message.
    pub path: Vec<usize>,
    pub msg: String,
}

impl fmt::Display for ValidationError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "at {:?}: {}", self.path, self.msg)
    }
}

impl std::error::Error for ValidationError {}

/// Validate the whole hierarchy; returns all problems found.
pub fn validate(g: &Graph) -> Vec<ValidationError> {
    let mut errs = Vec::new();
    validate_level(g, &mut vec![], &mut errs);
    errs
}

/// Convenience: panic with a readable report if invalid.
pub fn assert_valid(g: &Graph) {
    let errs = validate(g);
    assert!(
        errs.is_empty(),
        "block program invalid:\n{}",
        errs.iter()
            .map(|e| format!("  - {e}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

fn err(errs: &mut Vec<ValidationError>, path: &[usize], msg: String) {
    errs.push(ValidationError {
        path: path.to_vec(),
        msg,
    });
}

fn validate_level(g: &Graph, path: &mut Vec<usize>, errs: &mut Vec<ValidationError>) {
    if !g.is_acyclic() {
        err(errs, path, "graph has a cycle".into());
        return; // typing would recurse forever
    }

    for id in g.node_ids() {
        let n = g.node(id);
        // arity / connectivity
        for i in 0..n.in_arity() {
            if g.producer(port(id, i)).is_none() {
                err(
                    errs,
                    path,
                    format!("node {id} ({}) input port {i} unconnected", n.label),
                );
            }
        }
        for e in g.edges() {
            if e.dst.node == id && e.dst.port >= n.in_arity() {
                err(
                    errs,
                    path,
                    format!(
                        "node {id} ({}) has edge into nonexistent input port {}",
                        n.label, e.dst.port
                    ),
                );
            }
            if e.src.node == id && e.src.port >= n.out_arity() {
                err(
                    errs,
                    path,
                    format!(
                        "node {id} ({}) has edge from nonexistent output port {}",
                        n.label, e.src.port
                    ),
                );
            }
        }
    }

    // If connectivity is broken, typing may panic; bail early.
    if !errs.is_empty() {
        return;
    }

    for id in g.node_ids() {
        let n = g.node(id);
        match &n.kind {
            NodeKind::Func(f) => {
                let mut items = Vec::new();
                let mut ok = true;
                for i in 0..f.arity() {
                    let src = g.producer(port(id, i)).unwrap();
                    let t = g.out_ty(src);
                    if t.is_list() {
                        err(
                            errs,
                            path,
                            format!(
                                "func {id} ({}) input {i} is a list ({t}); functional \
                                 operators consume local items only",
                                n.label
                            ),
                        );
                        ok = false;
                    }
                    items.push(t.item);
                }
                if ok && f.out_item(&items).is_none() {
                    err(
                        errs,
                        path,
                        format!(
                            "func {id} ({}) item-type error: inputs {items:?}",
                            n.label
                        ),
                    );
                }
            }
            NodeKind::Reduce(_) | NodeKind::Head => {
                let src = g.producer(port(id, 0)).unwrap();
                let t = g.out_ty(src);
                if !t.is_list() {
                    err(
                        errs,
                        path,
                        format!("reduce/head {id} input is not a list ({t})"),
                    );
                }
            }
            NodeKind::Map(m) => {
                // port bindings
                for (i, mi) in m.inputs.iter().enumerate() {
                    let Some(inner) = m.inner.try_node(mi.inner_input) else {
                        err(
                            errs,
                            path,
                            format!("map {id} input {i} binds to removed inner node"),
                        );
                        continue;
                    };
                    let NodeKind::Input { ty: inner_ty } = &inner.kind else {
                        err(
                            errs,
                            path,
                            format!("map {id} input {i} binds to non-Input inner node"),
                        );
                        continue;
                    };
                    let Some(src) = g.producer(port(id, i)) else {
                        continue;
                    };
                    let outer_ty = g.out_ty(src);
                    let want: Ty = match mi.mode {
                        super::graph::ArgMode::Mapped => {
                            if !outer_ty.has_dim(&m.dim) {
                                err(
                                    errs,
                                    path,
                                    format!(
                                        "map {id} ({}) mapped input {i} type {outer_ty} \
                                         lacks dim {}",
                                        n.label, m.dim
                                    ),
                                );
                                continue;
                            }
                            outer_ty.strip(&m.dim)
                        }
                        super::graph::ArgMode::Bcast => outer_ty,
                    };
                    if *inner_ty != want {
                        err(
                            errs,
                            path,
                            format!(
                                "map {id} ({}) input {i}: inner Input declares {inner_ty}, \
                                 binding implies {want}",
                                n.label
                            ),
                        );
                    }
                }
                for (j, mo) in m.outputs.iter().enumerate() {
                    match m.inner.try_node(mo.inner_output) {
                        Some(inner) if matches!(inner.kind, NodeKind::Output) => {}
                        _ => err(
                            errs,
                            path,
                            format!("map {id} output {j} binds to missing/non-Output inner node"),
                        ),
                    }
                }
                // unbound inner inputs / outputs are dangling state
                for iid in m.inner.input_ids() {
                    if !m.inputs.iter().any(|mi| mi.inner_input == iid) {
                        err(
                            errs,
                            path,
                            format!("map {id}: inner Input {iid} not bound to any map port"),
                        );
                    }
                }
                for oid in m.inner.output_ids() {
                    if !m.outputs.iter().any(|mo| mo.inner_output == oid) {
                        err(
                            errs,
                            path,
                            format!("map {id}: inner Output {oid} not bound to any map port"),
                        );
                    }
                }
                path.push(id);
                validate_level(&m.inner, path, errs);
                path.pop();
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use crate::ir::graph::{map_over, ArgMode, Graph};
    use crate::ir::types::Ty;

    #[test]
    fn valid_program_passes() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        g.output("B", o[0]);
        assert!(validate(&g).is_empty());
    }

    #[test]
    fn unconnected_port_reported() {
        let mut g = Graph::new();
        let _a = g.input("A", Ty::block());
        let id = g.add_node(
            crate::ir::graph::NodeKind::Func(crate::ir::func::FuncOp::RowSum),
            "row_sum",
        );
        let _ = id;
        let errs = validate(&g);
        assert!(errs.iter().any(|e| e.msg.contains("unconnected")));
    }

    #[test]
    fn func_on_list_reported() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        // row_sum directly on a list: invalid.
        let r = g.func(crate::ir::func::FuncOp::RowSum, &[a]);
        g.output("B", r);
        let errs = validate(&g);
        assert!(errs.iter().any(|e| e.msg.contains("is a list")));
    }

    #[test]
    fn bad_mapped_dim_reported() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        g.output("B", o[0]);
        // Corrupt: rebind the map input to a value without dim N.
        let b = g.input("B2", Ty::blocks(&["K"]));
        let map_id = g
            .node_ids()
            .find(|&i| g.node(i).as_map().is_some())
            .unwrap();
        g.connect(b, crate::ir::graph::port(map_id, 0));
        let errs = validate(&g);
        assert!(!errs.is_empty());
    }
}
