//! The block program graph — the paper's §2 representation.
//!
//! A block program is a *hierarchical* DAG: map operator nodes contain inner
//! block-program graphs. Nodes are stored in an arena with tombstones so
//! `NodeId`s stay stable under rule rewrites; edges connect output *ports*
//! to input *ports* (one producer per input port, arbitrary fan-out per
//! output port).
//!
//! Buffering is *derived*, not stored: an edge is buffered iff its value
//! type is a list, or it is incident to a program input/output node (§2.1).

use super::dim::Dim;
use super::func::{FuncOp, ReduceOp};
use super::types::{Item, Ty};
use std::collections::{HashMap, HashSet, VecDeque};

pub type NodeId = usize;

/// One endpoint of an edge: output port `(node, port)` or input port
/// `(node, port)` depending on context.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct Port {
    pub node: NodeId,
    pub port: usize,
}

pub fn port(node: NodeId, port_ix: usize) -> Port {
    Port {
        node,
        port: port_ix,
    }
}

/// A directed edge from a producer output port to a consumer input port.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Edge {
    pub src: Port,
    pub dst: Port,
}

/// How a map consumes one of its inputs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ArgMode {
    /// The input is a list indexed by the map's dimension; each iteration
    /// sees one element (the first occurrence of the dim is stripped).
    Mapped,
    /// The input is passed to every iteration unchanged.
    Bcast,
}

/// How a map produces one of its outputs.
#[derive(Clone, PartialEq, Debug)]
pub enum OutMode {
    /// Iteration results are collected into a list over the map dimension.
    Collect,
    /// Iteration results are reduced on the fly (the result of Rule 3);
    /// lowers to a serial loop with an accumulator.
    Reduce(ReduceOp),
}

/// One input port of a map node.
#[derive(Clone, Debug)]
pub struct MapIn {
    /// The inner graph's `Input` node this port binds to.
    pub inner_input: NodeId,
    pub mode: ArgMode,
}

/// One output port of a map node.
#[derive(Clone, Debug)]
pub struct MapOut {
    /// The inner graph's `Output` node this port binds to.
    pub inner_output: NodeId,
    pub mode: OutMode,
}

/// A map operator: an embarrassingly parallel loop over `dim` whose body is
/// `inner`. (§2.1 "Map operators".)
#[derive(Clone, Debug)]
pub struct MapNode {
    pub dim: Dim,
    pub inner: Graph,
    pub inputs: Vec<MapIn>,
    pub outputs: Vec<MapOut>,
    /// Rule 7: iterate `1..X` instead of `0..X` (the first iteration was
    /// peeled off).
    pub skip_first: bool,
}

impl MapNode {
    /// True if any output is reduced (lowers to a serial loop).
    pub fn has_reduction(&self) -> bool {
        self.outputs
            .iter()
            .any(|o| matches!(o.mode, OutMode::Reduce(_)))
    }
}

#[derive(Clone, Debug)]
pub enum NodeKind {
    /// A program (or inner-graph) input. Top-level inputs reside in global
    /// memory; inner inputs are the map's per-iteration bindings.
    Input { ty: Ty },
    /// A program (or inner-graph) output; one input port.
    Output,
    /// A functional operator (Table 1); `arity` input ports, one output.
    Func(FuncOp),
    /// A map operator with an inner graph.
    Map(Box<MapNode>),
    /// A reduction operator: consumes a single-level list `[d]item`,
    /// produces the item-typed reduction over `d`.
    Reduce(ReduceOp),
    /// Rule 7 support: first element of a list (`[d]item -> item`).
    Head,
    /// Rule 7 support: prepend an item to a list over `dim`.
    Concat { dim: Dim },
    /// Anything the block-program vocabulary cannot express (§2.1
    /// "Miscellaneous operators"); opaque to every rule.
    Misc {
        tag: String,
        in_tys: Vec<Ty>,
        out_tys: Vec<Ty>,
    },
}

#[derive(Clone, Debug)]
pub struct Node {
    pub kind: NodeKind,
    /// Human-readable label; meaningful for inputs/outputs (`Q`, `KT`, `O`),
    /// best-effort elsewhere.
    pub label: String,
}

impl Node {
    pub fn in_arity(&self) -> usize {
        match &self.kind {
            NodeKind::Input { .. } => 0,
            NodeKind::Output => 1,
            NodeKind::Func(f) => f.arity(),
            NodeKind::Map(m) => m.inputs.len(),
            NodeKind::Reduce(_) | NodeKind::Head => 1,
            NodeKind::Concat { .. } => 2,
            NodeKind::Misc { in_tys, .. } => in_tys.len(),
        }
    }

    pub fn out_arity(&self) -> usize {
        match &self.kind {
            NodeKind::Input { .. } => 1,
            NodeKind::Output => 0,
            NodeKind::Func(_) | NodeKind::Reduce(_) | NodeKind::Head | NodeKind::Concat { .. } => 1,
            NodeKind::Map(m) => m.outputs.len(),
            NodeKind::Misc { out_tys, .. } => out_tys.len(),
        }
    }

    pub fn as_map(&self) -> Option<&MapNode> {
        match &self.kind {
            NodeKind::Map(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_map_mut(&mut self) -> Option<&mut MapNode> {
        match &mut self.kind {
            NodeKind::Map(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_io(&self) -> bool {
        matches!(self.kind, NodeKind::Input { .. } | NodeKind::Output)
    }
}

/// A block program graph (one level of the hierarchy).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    nodes: Vec<Option<Node>>,
    edges: Vec<Edge>,
    /// Program inputs that are *stateful buffers*: persistent across
    /// program invocations and read-extended along the named dimension
    /// each step (a KV cache grows along its sequence dim). The marks
    /// are metadata only — no rule or lowering changes shape because of
    /// them — but they survive fusion (the selector copies them onto
    /// segment input labels) so the serving layer can discover which
    /// buffers a plan expects to be session state, and `loopir` can tag
    /// the matching `BufDecl`s.
    state_dims: HashMap<String, Dim>,
}

impl Graph {
    pub fn new() -> Self {
        Graph::default()
    }

    // ---- construction ----------------------------------------------------

    pub fn add_node(&mut self, kind: NodeKind, label: impl Into<String>) -> NodeId {
        self.nodes.push(Some(Node {
            kind,
            label: label.into(),
        }));
        self.nodes.len() - 1
    }

    /// Add a program input of the given type; returns its output port.
    pub fn input(&mut self, label: impl Into<String>, ty: Ty) -> Port {
        let id = self.add_node(NodeKind::Input { ty }, label);
        port(id, 0)
    }

    /// Mark the program input `label` as a stateful buffer growing along
    /// `dim` (see the `state_dims` field docs). Idempotent; re-marking
    /// overwrites.
    pub fn mark_state(&mut self, label: impl Into<String>, dim: Dim) {
        self.state_dims.insert(label.into(), dim);
    }

    /// The growth dimension of input `label`, if it was marked stateful.
    pub fn state_dim(&self, label: &str) -> Option<&Dim> {
        self.state_dims.get(label)
    }

    /// Add a program output consuming `src`.
    pub fn output(&mut self, label: impl Into<String>, src: Port) -> NodeId {
        let id = self.add_node(NodeKind::Output, label);
        self.connect(src, port(id, 0));
        id
    }

    /// Add a functional operator; returns its output port.
    pub fn func(&mut self, op: FuncOp, args: &[Port]) -> Port {
        assert_eq!(
            op.arity(),
            args.len(),
            "func {op}: arity {} but {} args given",
            op.arity(),
            args.len()
        );
        let label = op.name().to_string();
        let id = self.add_node(NodeKind::Func(op), label);
        for (i, a) in args.iter().enumerate() {
            self.connect(*a, port(id, i));
        }
        port(id, 0)
    }

    /// Unary elementwise convenience.
    pub fn ew1(&mut self, expr: super::expr::Expr, a: Port) -> Port {
        self.func(FuncOp::Ew(expr), &[a])
    }

    /// Binary elementwise convenience.
    pub fn ew2(&mut self, expr: super::expr::Expr, a: Port, b: Port) -> Port {
        self.func(FuncOp::Ew(expr), &[a, b])
    }

    /// Add a reduction operator over the outermost list level of `src`.
    pub fn reduce(&mut self, op: ReduceOp, src: Port) -> Port {
        let id = self.add_node(NodeKind::Reduce(op), format!("reduce{op}"));
        self.connect(src, port(id, 0));
        port(id, 0)
    }

    /// Connect producer output port `src` to consumer input port `dst`,
    /// replacing any existing producer of `dst`.
    pub fn connect(&mut self, src: Port, dst: Port) {
        self.edges.retain(|e| e.dst != dst);
        self.edges.push(Edge { src, dst });
    }

    /// Remove the edge into `dst`, if any.
    pub fn disconnect(&mut self, dst: Port) {
        self.edges.retain(|e| e.dst != dst);
    }

    /// Remove a node and all incident edges.
    pub fn remove_node(&mut self, id: NodeId) {
        self.edges.retain(|e| e.src.node != id && e.dst.node != id);
        self.nodes[id] = None;
    }

    // ---- access -----------------------------------------------------------

    pub fn node(&self, id: NodeId) -> &Node {
        self.nodes[id]
            .as_ref()
            .unwrap_or_else(|| panic!("node {id} was removed"))
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        self.nodes[id]
            .as_mut()
            .unwrap_or_else(|| panic!("node {id} was removed"))
    }

    pub fn try_node(&self, id: NodeId) -> Option<&Node> {
        self.nodes.get(id).and_then(|n| n.as_ref())
    }

    pub fn contains(&self, id: NodeId) -> bool {
        self.nodes.get(id).is_some_and(|n| n.is_some())
    }

    /// Iterate live node ids in id order.
    pub fn node_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| n.as_ref().map(|_| i))
    }

    pub fn node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// The producer output port feeding input port `dst`, if connected.
    pub fn producer(&self, dst: Port) -> Option<Port> {
        self.edges.iter().find(|e| e.dst == dst).map(|e| e.src)
    }

    /// All consumer input ports fed by output port `src`.
    pub fn consumers(&self, src: Port) -> Vec<Port> {
        let mut v: Vec<Port> = self
            .edges
            .iter()
            .filter(|e| e.src == src)
            .map(|e| e.dst)
            .collect();
        v.sort();
        v
    }

    /// All consumer input ports fed by any output port of `node`.
    pub fn node_consumers(&self, node: NodeId) -> Vec<Port> {
        let mut v: Vec<Port> = self
            .edges
            .iter()
            .filter(|e| e.src.node == node)
            .map(|e| e.dst)
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// Rewire every consumer of `from` to consume `to` instead.
    pub fn rewire_consumers(&mut self, from: Port, to: Port) {
        for e in &mut self.edges {
            if e.src == from {
                e.src = to;
            }
        }
    }

    pub fn input_ids(&self) -> Vec<NodeId> {
        self.node_ids()
            .filter(|&i| matches!(self.node(i).kind, NodeKind::Input { .. }))
            .collect()
    }

    pub fn output_ids(&self) -> Vec<NodeId> {
        self.node_ids()
            .filter(|&i| matches!(self.node(i).kind, NodeKind::Output))
            .collect()
    }

    /// Find an input node by label (top-level program inputs are named).
    pub fn input_by_label(&self, label: &str) -> Option<Port> {
        self.input_ids()
            .into_iter()
            .find(|&i| self.node(i).label == label)
            .map(|i| port(i, 0))
    }

    // ---- typing -----------------------------------------------------------

    /// The type of the value on output port `p` (recursive inference).
    pub fn out_ty(&self, p: Port) -> Ty {
        let n = self.node(p.node);
        match &n.kind {
            NodeKind::Input { ty } => ty.clone(),
            NodeKind::Output => panic!("out_ty of an Output node"),
            NodeKind::Func(f) => {
                let ins: Vec<Item> = (0..f.arity())
                    .map(|i| {
                        let src = self
                            .producer(port(p.node, i))
                            .unwrap_or_else(|| panic!("func {} input {i} unconnected", n.label));
                        let t = self.out_ty(src);
                        assert!(
                            !t.is_list(),
                            "func {} input {i} has list type {t}",
                            n.label
                        );
                        t.item
                    })
                    .collect();
                let item = f.out_item(&ins).unwrap_or_else(|| {
                    panic!("func {} type error with inputs {ins:?}", n.label)
                });
                Ty::item(item)
            }
            NodeKind::Map(m) => {
                let out = &m.outputs[p.port];
                let inner_out = m.inner.node(out.inner_output);
                assert!(matches!(inner_out.kind, NodeKind::Output));
                let src = m
                    .inner
                    .producer(port(out.inner_output, 0))
                    .expect("map inner output unconnected");
                let t = m.inner.out_ty(src);
                match &out.mode {
                    OutMode::Collect => t.collect(&m.dim),
                    OutMode::Reduce(_) => t,
                }
            }
            NodeKind::Reduce(_) => {
                let src = self.producer(port(p.node, 0)).expect("reduce unconnected");
                self.out_ty(src).reduce()
            }
            NodeKind::Head => {
                let src = self.producer(port(p.node, 0)).expect("head unconnected");
                self.out_ty(src).reduce()
            }
            NodeKind::Concat { dim } => {
                let src = self
                    .producer(port(p.node, 0))
                    .expect("concat item unconnected");
                self.out_ty(src).collect(dim)
            }
            NodeKind::Misc { out_tys, .. } => out_tys[p.port].clone(),
        }
    }

    /// The declared type of input node `id`.
    pub fn input_ty(&self, id: NodeId) -> &Ty {
        match &self.node(id).kind {
            NodeKind::Input { ty } => ty,
            _ => panic!("node {id} is not an Input"),
        }
    }

    pub fn set_input_ty(&mut self, id: NodeId, new_ty: Ty) {
        match &mut self.node_mut(id).kind {
            NodeKind::Input { ty } => *ty = new_ty,
            _ => panic!("node {id} is not an Input"),
        }
    }

    // ---- graph algorithms ---------------------------------------------------

    /// Node-level adjacency: successors of `id`.
    pub fn successors(&self, id: NodeId) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self
            .edges
            .iter()
            .filter(|e| e.src.node == id)
            .map(|e| e.dst.node)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn predecessors(&self, id: NodeId) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self
            .edges
            .iter()
            .filter(|e| e.dst.node == id)
            .map(|e| e.src.node)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Is `to` reachable from `from` (following edges forward)? `from == to`
    /// counts as reachable only via a real path (cycle).
    pub fn reaches(&self, from: NodeId, to: NodeId) -> bool {
        self.reaches_excluding(from, to, &[])
    }

    /// Reachability ignoring the given direct edges (for Rule 1's "no
    /// indirect path" condition).
    pub fn reaches_excluding(&self, from: NodeId, to: NodeId, skip: &[Edge]) -> bool {
        let mut seen = HashSet::new();
        let mut stack = vec![from];
        let mut first = true;
        while let Some(n) = stack.pop() {
            if !first && n == to {
                return true;
            }
            first = false;
            for e in &self.edges {
                if e.src.node == n && !skip.iter().any(|s| s.src == e.src && s.dst == e.dst) {
                    if e.dst.node == to {
                        return true;
                    }
                    if seen.insert(e.dst.node) {
                        stack.push(e.dst.node);
                    }
                }
            }
        }
        false
    }

    /// Kahn topological order over live nodes. Panics on a cycle.
    pub fn topo_order(&self) -> Vec<NodeId> {
        let ids: Vec<NodeId> = self.node_ids().collect();
        let mut indeg: HashMap<NodeId, usize> = ids.iter().map(|&i| (i, 0)).collect();
        let mut seen_pairs = HashSet::new();
        for e in &self.edges {
            if seen_pairs.insert((e.src.node, e.dst.node)) {
                *indeg.get_mut(&e.dst.node).unwrap() += 1;
            }
        }
        let mut q: VecDeque<NodeId> = ids.iter().copied().filter(|i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(ids.len());
        let mut done_pairs = HashSet::new();
        while let Some(n) = q.pop_front() {
            order.push(n);
            for s in self.successors(n) {
                if done_pairs.insert((n, s)) {
                    let d = indeg.get_mut(&s).unwrap();
                    *d -= 1;
                    if *d == 0 {
                        q.push_back(s);
                    }
                }
            }
        }
        assert_eq!(
            order.len(),
            ids.len(),
            "topo_order: graph has a cycle ({} of {} nodes ordered)",
            order.len(),
            ids.len()
        );
        order
    }

    pub fn is_acyclic(&self) -> bool {
        let ids: Vec<NodeId> = self.node_ids().collect();
        let mut indeg: HashMap<NodeId, usize> = ids.iter().map(|&i| (i, 0)).collect();
        let mut seen_pairs = HashSet::new();
        for e in &self.edges {
            if seen_pairs.insert((e.src.node, e.dst.node)) {
                *indeg.get_mut(&e.dst.node).unwrap() += 1;
            }
        }
        let mut q: VecDeque<NodeId> = ids.iter().copied().filter(|i| indeg[i] == 0).collect();
        let mut count = 0;
        let mut done_pairs = HashSet::new();
        while let Some(n) = q.pop_front() {
            count += 1;
            for s in self.successors(n) {
                if done_pairs.insert((n, s)) {
                    let d = indeg.get_mut(&s).unwrap();
                    *d -= 1;
                    if *d == 0 {
                        q.push_back(s);
                    }
                }
            }
        }
        count == ids.len()
    }

    /// Copy all nodes and edges of `other` into `self`; returns the id
    /// remapping (old id -> new id). Tombstone slots are preserved so edge
    /// ports remap by offset.
    pub fn absorb(&mut self, other: Graph) -> HashMap<NodeId, NodeId> {
        let offset = self.nodes.len();
        let mut remap = HashMap::new();
        for (i, n) in other.nodes.into_iter().enumerate() {
            if n.is_some() {
                remap.insert(i, offset + i);
            }
            self.nodes.push(n);
        }
        for e in other.edges {
            self.edges.push(Edge {
                src: port(remap[&e.src.node], e.src.port),
                dst: port(remap[&e.dst.node], e.dst.port),
            });
        }
        remap
    }

    /// All buffered edges at this level: list-typed values or edges incident
    /// to this graph's Input/Output nodes (§2.1). Returns (edge, type).
    pub fn buffered_edges(&self) -> Vec<(Edge, Ty)> {
        self.edges
            .iter()
            .filter_map(|e| {
                let ty = self.out_ty(e.src);
                let io = self.node(e.src.node).is_io() || self.node(e.dst.node).is_io();
                if ty.is_list() || io {
                    Some((*e, ty))
                } else {
                    None
                }
            })
            .collect()
    }

    /// Buffered edges that are *interior*: not incident to Input/Output
    /// nodes at this level. Fully fused programs have none, at any level
    /// (the paper's termination criterion: "The only remaining buffered
    /// edges are those that are incident with input or output nodes").
    pub fn interior_buffered_edges(&self) -> Vec<(Edge, Ty)> {
        self.edges
            .iter()
            .filter_map(|e| {
                if self.node(e.src.node).is_io() || self.node(e.dst.node).is_io() {
                    return None;
                }
                let ty = self.out_ty(e.src);
                if ty.is_list() {
                    Some((*e, ty))
                } else {
                    None
                }
            })
            .collect()
    }

    /// Count interior buffered edges recursively through the hierarchy.
    pub fn interior_buffered_count_recursive(&self) -> usize {
        let mut n = self.interior_buffered_edges().len();
        for id in self.node_ids() {
            if let Some(m) = self.node(id).as_map() {
                n += m.inner.interior_buffered_count_recursive();
            }
        }
        n
    }

    /// Total node count recursively through the hierarchy.
    pub fn node_count_recursive(&self) -> usize {
        let mut n = self.node_count();
        for id in self.node_ids() {
            if let Some(m) = self.node(id).as_map() {
                n += m.inner.node_count_recursive();
            }
        }
        n
    }

    /// Maximum map-nesting depth.
    pub fn depth(&self) -> usize {
        let mut d = 0;
        for id in self.node_ids() {
            if let Some(m) = self.node(id).as_map() {
                d = d.max(1 + m.inner.depth());
            }
        }
        d
    }
}

// ---------------------------------------------------------------------------
// Map construction helper
// ---------------------------------------------------------------------------

/// Body-under-construction of a map operator; passed to the closure of
/// [`map_over`]. `g` is the inner graph; use [`MapBody::collect`] /
/// [`MapBody::reduce_out`] to register outputs.
pub struct MapBody {
    pub g: Graph,
    outputs: Vec<(Port, OutMode)>,
}

impl MapBody {
    /// Register `src` as a collected output of the map.
    pub fn collect(&mut self, src: Port) {
        self.outputs.push((src, OutMode::Collect));
    }

    /// Register `src` as an on-the-fly reduced output of the map.
    pub fn reduce_out(&mut self, src: Port, op: ReduceOp) {
        self.outputs.push((src, OutMode::Reduce(op)));
    }
}

/// Build a map node over `dim` in `parent`. `args` are (outer port, mode)
/// pairs; the closure receives the map body and the inner ports bound to
/// each arg, and must register at least one output. Returns the map's
/// output ports in registration order.
pub fn map_over(
    parent: &mut Graph,
    dim: impl Into<Dim>,
    args: &[(Port, ArgMode)],
    build: impl FnOnce(&mut MapBody, &[Port]),
) -> Vec<Port> {
    let dim = dim.into();
    let mut body = MapBody {
        g: Graph::new(),
        outputs: vec![],
    };
    let mut inner_ports = Vec::with_capacity(args.len());
    let mut map_ins = Vec::with_capacity(args.len());
    for (i, (outer, mode)) in args.iter().enumerate() {
        let outer_ty = parent.out_ty(*outer);
        let inner_ty = match mode {
            ArgMode::Mapped => outer_ty.strip(&dim),
            ArgMode::Bcast => outer_ty,
        };
        let label = format!("in{i}");
        let ip = body.g.input(label, inner_ty);
        inner_ports.push(ip);
        map_ins.push(MapIn {
            inner_input: ip.node,
            mode: *mode,
        });
    }
    build(&mut body, &inner_ports);
    assert!(
        !body.outputs.is_empty(),
        "map_over: body registered no outputs"
    );
    let mut map_outs = Vec::with_capacity(body.outputs.len());
    for (j, (src, mode)) in body.outputs.iter().enumerate() {
        let out_id = body.g.add_node(NodeKind::Output, format!("out{j}"));
        body.g.connect(*src, port(out_id, 0));
        map_outs.push(MapOut {
            inner_output: out_id,
            mode: mode.clone(),
        });
    }
    let n_out = map_outs.len();
    let map_id = parent.add_node(
        NodeKind::Map(Box::new(MapNode {
            dim: dim.clone(),
            inner: body.g,
            inputs: map_ins,
            outputs: map_outs,
            skip_first: false,
        })),
        format!("map{dim}"),
    );
    for (i, (outer, _)) in args.iter().enumerate() {
        parent.connect(*outer, port(map_id, i));
    }
    (0..n_out).map(|j| port(map_id, j)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;

    /// The §2.1 running example: apply (x-s)/d to each block of a list.
    fn ew_map_program() -> Graph {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let outs = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let e = Expr::var(0)
                .sub(Expr::cst(1.0))
                .div(Expr::cst(2.0));
            let r = mb.g.ew1(e, ins[0]);
            mb.collect(r);
        });
        g.output("B", outs[0]);
        g
    }

    #[test]
    fn build_and_type_simple_map() {
        let g = ew_map_program();
        assert_eq!(g.node_count(), 3); // input, map, output
        let map_id = g
            .node_ids()
            .find(|&i| g.node(i).as_map().is_some())
            .unwrap();
        assert_eq!(g.out_ty(port(map_id, 0)), Ty::blocks(&["N"]));
        assert!(g.is_acyclic());
    }

    #[test]
    fn nested_maps_type() {
        // A[M,N] -> elementwise -> B[M,N] via nested maps.
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["M", "N"]));
        let outs = map_over(&mut g, "M", &[(a, ArgMode::Mapped)], |mb, ins| {
            let inner = map_over(
                &mut mb.g,
                "N",
                &[(ins[0], ArgMode::Mapped)],
                |mb2, ins2| {
                    let r = mb2.g.ew1(Expr::var(0).exp(), ins2[0]);
                    mb2.collect(r);
                },
            );
            mb.collect(inner[0]);
        });
        g.output("B", outs[0]);
        let map_id = g
            .node_ids()
            .find(|&i| g.node(i).as_map().is_some())
            .unwrap();
        assert_eq!(g.out_ty(port(map_id, 0)), Ty::blocks(&["M", "N"]));
        assert_eq!(g.depth(), 2);
        assert_eq!(g.node_count_recursive(), 3 + 3 + 3);
    }

    #[test]
    fn reduce_node_types() {
        // sum over N of row_sum per block: Map(N){row_sum} -> Reduce(N).
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let outs = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.collect(r);
        });
        let red = g.reduce(ReduceOp::Add, outs[0]);
        assert_eq!(g.out_ty(red), Ty::vector());
        g.output("c", red);
        assert!(g.is_acyclic());
    }

    #[test]
    fn reduced_map_output_is_item() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let outs = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.reduce_out(r, ReduceOp::Add);
        });
        assert_eq!(g.out_ty(outs[0]), Ty::vector());
        g.output("c", outs[0]);
    }

    #[test]
    fn buffered_edge_census() {
        let g = ew_map_program();
        // input->map and map->output are buffered (I/O + list); none interior.
        assert_eq!(g.buffered_edges().len(), 2);
        assert!(g.interior_buffered_edges().is_empty());
    }

    #[test]
    fn interior_buffered_detected() {
        // Two chained maps materialize an interior list.
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o1 = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        let o2 = map_over(&mut g, "N", &[(o1[0], ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).neg(), ins[0]);
            mb.collect(r);
        });
        g.output("B", o2[0]);
        assert_eq!(g.interior_buffered_edges().len(), 1);
        assert_eq!(g.interior_buffered_count_recursive(), 1);
    }

    #[test]
    fn reachability_and_topo() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::block());
        let x = g.ew1(Expr::var(0).exp(), a);
        let y = g.ew1(Expr::var(0).neg(), x);
        g.output("B", y);
        assert!(g.reaches(a.node, y.node));
        assert!(!g.reaches(y.node, a.node));
        let order = g.topo_order();
        let pos = |id: NodeId| order.iter().position(|&x| x == id).unwrap();
        assert!(pos(a.node) < pos(x.node));
        assert!(pos(x.node) < pos(y.node));
    }

    #[test]
    fn reaches_excluding_direct_edge() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::block());
        let u = g.ew1(Expr::var(0).exp(), a);
        let v = g.ew1(Expr::var(0).neg(), u);
        g.output("B", v);
        let direct = Edge {
            src: u,
            dst: port(v.node, 0),
        };
        // Only path u->v is the direct edge; excluding it, unreachable.
        assert!(!g.reaches_excluding(u.node, v.node, &[direct]));
    }

    #[test]
    fn rewire_and_remove() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::block());
        let b = g.input("B", Ty::block());
        let x = g.ew1(Expr::var(0).exp(), a);
        g.output("O", x);
        g.rewire_consumers(a, b);
        assert_eq!(g.producer(port(x.node, 0)), Some(b));
        g.remove_node(a.node);
        assert!(!g.contains(a.node));
        assert!(g.is_acyclic());
    }

    #[test]
    fn absorb_remaps_ids() {
        let mut g1 = Graph::new();
        let a = g1.input("A", Ty::block());
        g1.output("OA", a);
        let mut g2 = Graph::new();
        let b = g2.input("B", Ty::block());
        let e = g2.ew1(Expr::var(0).neg(), b);
        g2.output("OB", e);
        let n2 = g2.node_count();
        let remap = g1.absorb(g2);
        assert_eq!(remap.len(), n2);
        assert_eq!(g1.node_count(), 2 + n2);
        assert!(g1.is_acyclic());
    }
}
