//! The block program intermediate representation (paper §2).
//!
//! * [`dim`] — named iteration dimensions and concrete size environments.
//! * [`types`] — item/list value types; buffering is derived from types.
//! * [`expr`] — symbolic scalar expressions for elementwise operators.
//! * [`exprvm`] — the batched slice-at-a-time VM those expressions
//!   compile to for block/vector evaluation (bit-identical to [`expr`]'s
//!   scalar stack machine).
//! * [`func`] — the Table-1 functional operator vocabulary.
//! * [`graph`] — the hierarchical DAG itself plus builders and algorithms.
//! * [`validate`] — structural and type invariants.
//! * [`display`] — text and Graphviz renderers.

pub mod dim;
pub mod display;
pub mod expr;
pub mod exprvm;
pub mod func;
pub mod graph;
pub mod types;
pub mod validate;

pub use dim::{Dim, DimSizes};
pub use expr::Expr;
pub use func::{FuncOp, ReduceOp};
pub use graph::{map_over, port, ArgMode, Graph, MapNode, Node, NodeId, NodeKind, OutMode, Port};
pub use types::{Item, Ty};
