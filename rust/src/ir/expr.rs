//! Symbolic scalar expressions for elementwise operators.
//!
//! §2.1: "An elementwise operation is any scalar function, which is applied
//! independently to each element of a block or vector." Keeping the scalar
//! function as a small AST (instead of an opaque closure) is what lets
//! Rule 9 *compose* consecutive elementwise operators into one, lets the
//! printer render the paper's listings (`t4 = exp(t3*(DD**-0.5))`), and lets
//! the interpreter evaluate fused programs.
//!
//! Expressions reference their operator's inputs positionally via
//! [`Expr::Var`] and named compile-time constants (the paper's `DD`, `KK`)
//! via [`Expr::Param`].

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum UnOp {
    Neg,
    Exp,
    Log,
    Sqrt,
    Recip,
    Abs,
}

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Pow,
    Max,
    Min,
}

/// A scalar expression over positional inputs `Var(0..arity)`.
#[derive(Clone, PartialEq, Debug)]
pub enum Expr {
    /// The i-th input of the elementwise operator.
    Var(usize),
    /// A literal constant.
    Const(f64),
    /// A named program parameter (e.g. `DD` = model width, `KK` = row length).
    Param(String),
    Un(UnOp, Box<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn var(i: usize) -> Expr {
        Expr::Var(i)
    }
    pub fn cst(v: f64) -> Expr {
        Expr::Const(v)
    }
    pub fn param(name: &str) -> Expr {
        Expr::Param(name.to_string())
    }

    pub fn neg(self) -> Expr {
        Expr::Un(UnOp::Neg, Box::new(self))
    }
    pub fn exp(self) -> Expr {
        Expr::Un(UnOp::Exp, Box::new(self))
    }
    pub fn log(self) -> Expr {
        Expr::Un(UnOp::Log, Box::new(self))
    }
    pub fn sqrt(self) -> Expr {
        Expr::Un(UnOp::Sqrt, Box::new(self))
    }
    pub fn recip(self) -> Expr {
        Expr::Un(UnOp::Recip, Box::new(self))
    }
    pub fn abs(self) -> Expr {
        Expr::Un(UnOp::Abs, Box::new(self))
    }

    pub fn add(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Add, Box::new(self), Box::new(o))
    }
    pub fn sub(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Sub, Box::new(self), Box::new(o))
    }
    pub fn mul(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Mul, Box::new(self), Box::new(o))
    }
    pub fn div(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Div, Box::new(self), Box::new(o))
    }
    pub fn pow(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Pow, Box::new(self), Box::new(o))
    }
    pub fn max(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Max, Box::new(self), Box::new(o))
    }
    pub fn min(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Min, Box::new(self), Box::new(o))
    }

    /// `x / (1 + exp(-x))` — Swish/SiLU, used by FFN-SwiGLU.
    pub fn swish(x: Expr) -> Expr {
        x.clone().div(Expr::cst(1.0).add(x.neg().exp()))
    }

    /// `max(x, 0)` — ReLU, used by the §1 motivating example.
    pub fn relu(x: Expr) -> Expr {
        x.max(Expr::cst(0.0))
    }

    /// `exp(x − shift) / (exp(x − shift) + 0.125)` — the exp/sub/div
    /// chain left after fusing a numerically-safe softmax (shifted
    /// numerator over a shifted partial denominator). Shared by the
    /// Ew-heavy backend-parity programs and the expression-VM bench so
    /// they certify and measure the same expression.
    pub fn softmax_tail(x: Expr, shift: Expr) -> Expr {
        let s = x.sub(shift).exp();
        s.clone().div(s.add(Expr::cst(0.125)))
    }

    /// `0.5·x·(1 + sign(x)·(1 − exp(−|x|·(a + b·|x|))))` — a tanh-free
    /// GELU-style erf approximation built from exp/abs, with the sign
    /// recovered as `x/(|x|+ε)`. Shared by the Ew-heavy backend-parity
    /// programs and the expression-VM bench (see [`Expr::softmax_tail`]).
    pub fn gelu_erf(x: Expr) -> Expr {
        let absx = x.clone().abs();
        let inner = absx
            .clone()
            .mul(Expr::cst(1.13).add(Expr::cst(0.273).mul(absx.clone())));
        let mag = Expr::cst(1.0).sub(inner.neg().exp());
        let sign = x.clone().div(absx.add(Expr::cst(1e-6)));
        Expr::cst(0.5).mul(x).mul(Expr::cst(1.0).add(sign.mul(mag)))
    }

    /// Highest input index referenced, plus one (0 if no inputs referenced).
    pub fn arity(&self) -> usize {
        match self {
            Expr::Var(i) => i + 1,
            Expr::Const(_) | Expr::Param(_) => 0,
            Expr::Un(_, a) => a.arity(),
            Expr::Bin(_, a, b) => a.arity().max(b.arity()),
        }
    }

    /// Substitute each `Var(i)` with `subs[i]` (used by Rule 9 composition).
    pub fn substitute(&self, subs: &[Expr]) -> Expr {
        match self {
            Expr::Var(i) => subs
                .get(*i)
                .cloned()
                .unwrap_or_else(|| panic!("Expr::substitute: no substitution for Var({i})")),
            Expr::Const(c) => Expr::Const(*c),
            Expr::Param(p) => Expr::Param(p.clone()),
            Expr::Un(op, a) => Expr::Un(*op, Box::new(a.substitute(subs))),
            Expr::Bin(op, a, b) => Expr::Bin(
                *op,
                Box::new(a.substitute(subs)),
                Box::new(b.substitute(subs)),
            ),
        }
    }

    /// Shift every `Var(i)` by `offset` (used when merging input lists).
    pub fn shift_vars(&self, offset: usize) -> Expr {
        match self {
            Expr::Var(i) => Expr::Var(i + offset),
            Expr::Const(c) => Expr::Const(*c),
            Expr::Param(p) => Expr::Param(p.clone()),
            Expr::Un(op, a) => Expr::Un(*op, Box::new(a.shift_vars(offset))),
            Expr::Bin(op, a, b) => Expr::Bin(
                *op,
                Box::new(a.shift_vars(offset)),
                Box::new(b.shift_vars(offset)),
            ),
        }
    }

    /// Remap variable indices through `map` (used to dedupe merged inputs).
    pub fn remap_vars(&self, map: &[usize]) -> Expr {
        match self {
            Expr::Var(i) => Expr::Var(map[*i]),
            Expr::Const(c) => Expr::Const(*c),
            Expr::Param(p) => Expr::Param(p.clone()),
            Expr::Un(op, a) => Expr::Un(*op, Box::new(a.remap_vars(map))),
            Expr::Bin(op, a, b) => {
                Expr::Bin(*op, Box::new(a.remap_vars(map)), Box::new(b.remap_vars(map)))
            }
        }
    }

    /// Evaluate with concrete input values and parameter environment.
    pub fn eval(&self, args: &[f32], params: &BTreeMap<String, f32>) -> f32 {
        match self {
            Expr::Var(i) => args[*i],
            Expr::Const(c) => *c as f32,
            Expr::Param(p) => *params
                .get(p)
                .unwrap_or_else(|| panic!("Expr::eval: missing parameter {p}")),
            Expr::Un(op, a) => {
                let x = a.eval(args, params);
                match op {
                    UnOp::Neg => -x,
                    UnOp::Exp => x.exp(),
                    UnOp::Log => x.ln(),
                    UnOp::Sqrt => x.sqrt(),
                    UnOp::Recip => 1.0 / x,
                    UnOp::Abs => x.abs(),
                }
            }
            Expr::Bin(op, a, b) => {
                let x = a.eval(args, params);
                let y = b.eval(args, params);
                match op {
                    BinOp::Add => x + y,
                    BinOp::Sub => x - y,
                    BinOp::Mul => x * y,
                    BinOp::Div => x / y,
                    BinOp::Pow => x.powf(y),
                    BinOp::Max => x.max(y),
                    BinOp::Min => x.min(y),
                }
            }
        }
    }

    /// All parameter names referenced by the expression.
    pub fn params(&self, out: &mut Vec<String>) {
        match self {
            Expr::Param(p) => {
                if !out.contains(p) {
                    out.push(p.clone());
                }
            }
            Expr::Un(_, a) => a.params(out),
            Expr::Bin(_, a, b) => {
                a.params(out);
                b.params(out);
            }
            _ => {}
        }
    }

    /// Render with the given argument names, paper style:
    /// `exp(t3*(DD**-0.5))`, `t10/(1+exp(-t10))`.
    pub fn render(&self, args: &[String]) -> String {
        self.render_prec(args, 0)
    }

    fn render_prec(&self, args: &[String], parent: u8) -> String {
        // precedence: 1 add/sub/min/max, 2 mul/div, 3 pow, 4 unary/atom
        let (s, prec) = match self {
            Expr::Var(i) => (
                args.get(*i)
                    .cloned()
                    .unwrap_or_else(|| format!("arg{i}")),
                4,
            ),
            Expr::Const(c) => {
                let s = if *c == c.trunc() && c.abs() < 1e9 {
                    format!("{}", *c as i64)
                } else {
                    format!("{c}")
                };
                (s, if *c < 0.0 { 3 } else { 4 })
            }
            Expr::Param(p) => (p.clone(), 4),
            Expr::Un(op, a) => match op {
                UnOp::Neg => (format!("-{}", a.render_prec(args, 3)), 2),
                UnOp::Exp => (format!("exp({})", a.render_prec(args, 0)), 4),
                UnOp::Log => (format!("log({})", a.render_prec(args, 0)), 4),
                UnOp::Sqrt => (format!("sqrt({})", a.render_prec(args, 0)), 4),
                UnOp::Recip => (format!("1/{}", a.render_prec(args, 3)), 2),
                UnOp::Abs => (format!("abs({})", a.render_prec(args, 0)), 4),
            },
            Expr::Bin(op, a, b) => match op {
                BinOp::Add => (
                    format!("{}+{}", a.render_prec(args, 1), b.render_prec(args, 1)),
                    1,
                ),
                BinOp::Sub => (
                    format!("{}-{}", a.render_prec(args, 1), b.render_prec(args, 2)),
                    1,
                ),
                BinOp::Mul => (
                    format!("{}*{}", a.render_prec(args, 2), b.render_prec(args, 2)),
                    2,
                ),
                BinOp::Div => (
                    format!("{}/{}", a.render_prec(args, 2), b.render_prec(args, 3)),
                    2,
                ),
                BinOp::Pow => (
                    format!("{}**{}", a.render_prec(args, 4), b.render_prec(args, 4)),
                    3,
                ),
                BinOp::Max => (
                    format!(
                        "max({},{})",
                        a.render_prec(args, 0),
                        b.render_prec(args, 0)
                    ),
                    4,
                ),
                BinOp::Min => (
                    format!(
                        "min({},{})",
                        a.render_prec(args, 0),
                        b.render_prec(args, 0)
                    ),
                    4,
                ),
            },
        };
        if prec < parent {
            format!("({s})")
        } else {
            s
        }
    }
}

/// A flattened, parameter-resolved form of an [`Expr`] for the hot
/// evaluation path: postfix ops over a small stack, no recursion, no
/// per-element allocation, no parameter lookups.
#[derive(Clone, Debug)]
pub struct CompiledExpr {
    tape: Vec<TapeOp>,
    pub max_stack: usize,
    /// Input arity of the source expression (kept so callers that only
    /// hold the compiled tape — the tape-based engine — can size args).
    pub arity: usize,
}

/// One postfix instruction of a [`CompiledExpr`]. Public so the batched
/// expression VM ([`super::exprvm`]) can translate the tape into its
/// slice-at-a-time program; the scalar evaluator below stays the
/// semantic reference.
#[derive(Clone, Copy, Debug)]
pub enum TapeOp {
    PushVar(usize),
    PushConst(f32),
    Un(UnOp),
    Bin(BinOp),
}

impl Expr {
    /// Flatten to a postfix tape, resolving named parameters now.
    pub fn compile(&self, params: &BTreeMap<String, f32>) -> CompiledExpr {
        fn rec(e: &Expr, params: &BTreeMap<String, f32>, tape: &mut Vec<TapeOp>) {
            match e {
                Expr::Var(i) => tape.push(TapeOp::PushVar(*i)),
                Expr::Const(c) => tape.push(TapeOp::PushConst(*c as f32)),
                Expr::Param(p) => tape.push(TapeOp::PushConst(
                    *params
                        .get(p)
                        .unwrap_or_else(|| panic!("compile: missing parameter {p}")),
                )),
                Expr::Un(op, a) => {
                    rec(a, params, tape);
                    tape.push(TapeOp::Un(*op));
                }
                Expr::Bin(op, a, b) => {
                    rec(a, params, tape);
                    rec(b, params, tape);
                    tape.push(TapeOp::Bin(*op));
                }
            }
        }
        let mut tape = Vec::new();
        rec(self, params, &mut tape);
        let mut depth = 0usize;
        let mut max = 0usize;
        for op in &tape {
            match op {
                TapeOp::PushVar(_) | TapeOp::PushConst(_) => depth += 1,
                TapeOp::Un(_) => {}
                TapeOp::Bin(_) => depth -= 1,
            }
            max = max.max(depth);
        }
        CompiledExpr {
            tape,
            max_stack: max,
            arity: self.arity(),
        }
    }
}

impl CompiledExpr {
    /// The postfix instruction tape (read-only; consumed by
    /// [`super::exprvm::ExprVm::from_compiled`]).
    pub fn ops(&self) -> &[TapeOp] {
        &self.tape
    }

    /// Evaluate on the given argument values; `stack` is caller-provided
    /// scratch (cleared here) to keep the per-element path allocation-free.
    #[inline]
    pub fn eval_with(&self, args: &[f32], stack: &mut Vec<f32>) -> f32 {
        stack.clear();
        for op in &self.tape {
            match op {
                TapeOp::PushVar(i) => stack.push(args[*i]),
                TapeOp::PushConst(c) => stack.push(*c),
                TapeOp::Un(u) => {
                    let x = stack.last_mut().unwrap();
                    *x = match u {
                        UnOp::Neg => -*x,
                        UnOp::Exp => x.exp(),
                        UnOp::Log => x.ln(),
                        UnOp::Sqrt => x.sqrt(),
                        UnOp::Recip => 1.0 / *x,
                        UnOp::Abs => x.abs(),
                    };
                }
                TapeOp::Bin(b) => {
                    let y = stack.pop().unwrap();
                    let x = stack.last_mut().unwrap();
                    *x = match b {
                        BinOp::Add => *x + y,
                        BinOp::Sub => *x - y,
                        BinOp::Mul => *x * y,
                        BinOp::Div => *x / y,
                        BinOp::Pow => x.powf(y),
                        BinOp::Max => x.max(y),
                        BinOp::Min => x.min(y),
                    };
                }
            }
        }
        stack[0]
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let names: Vec<String> = (0..self.arity()).map(|i| format!("x{i}")).collect();
        f.write_str(&self.render(&names))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_params() -> BTreeMap<String, f32> {
        BTreeMap::new()
    }

    #[test]
    fn eval_basic() {
        // (x - s)/d with s=1, d=2
        let e = Expr::var(0).sub(Expr::cst(1.0)).div(Expr::cst(2.0));
        assert_eq!(e.eval(&[5.0], &no_params()), 2.0);
    }

    #[test]
    fn eval_param() {
        let e = Expr::var(0).mul(Expr::param("DD").pow(Expr::cst(-0.5)));
        let mut p = BTreeMap::new();
        p.insert("DD".to_string(), 4.0);
        assert_eq!(e.eval(&[6.0], &p), 3.0);
    }

    #[test]
    fn swish_matches_formula() {
        let e = Expr::swish(Expr::var(0));
        let x = 1.3_f32;
        let want = x / (1.0 + (-x).exp());
        assert!((e.eval(&[x], &no_params()) - want).abs() < 1e-6);
    }

    #[test]
    fn relu_clamps() {
        let e = Expr::relu(Expr::var(0));
        assert_eq!(e.eval(&[-3.0], &no_params()), 0.0);
        assert_eq!(e.eval(&[3.0], &no_params()), 3.0);
    }

    #[test]
    fn substitute_composes() {
        // g(y) = exp(y); f(x) = x*2 ; g∘f = exp(x*2)
        let g = Expr::var(0).exp();
        let f = Expr::var(0).mul(Expr::cst(2.0));
        let gf = g.substitute(&[f]);
        assert!((gf.eval(&[1.0], &no_params()) - 2.0_f32.exp()).abs() < 1e-5);
    }

    #[test]
    fn arity_counts_max_var() {
        let e = Expr::var(2).add(Expr::var(0));
        assert_eq!(e.arity(), 3);
    }

    #[test]
    fn render_paper_style() {
        let e = Expr::var(0).mul(Expr::param("DD").pow(Expr::cst(-0.5))).exp();
        assert_eq!(e.render(&["t3".into()]), "exp(t3*DD**(-0.5))");
        let sw = Expr::swish(Expr::var(0));
        assert_eq!(sw.render(&["t10".into()]), "t10/(1+exp(-t10))");
        let r = Expr::var(0).recip();
        assert_eq!(r.render(&["t5".into()]), "1/t5");
    }

    #[test]
    fn render_layernorm_std() {
        // (s2/KK - mu**2)**(-0.5)
        let e = Expr::var(0)
            .div(Expr::param("KK"))
            .sub(Expr::var(1).pow(Expr::cst(2.0)))
            .pow(Expr::cst(-0.5));
        assert_eq!(
            e.render(&["t13".into(), "t5".into()]),
            "(t13/KK-t5**2)**(-0.5)"
        );
    }

    #[test]
    fn shift_and_remap() {
        let e = Expr::var(0).add(Expr::var(1));
        let s = e.shift_vars(2);
        assert_eq!(s.arity(), 4);
        let r = s.remap_vars(&[9, 9, 0, 0]);
        assert_eq!(r.arity(), 1);
    }
}
