//! Paper-style code listings from Loop IR.
//!
//! Renders the exact notation of the paper's examples:
//!
//! ```text
//! forall m in range(M):
//!   for n in range(N):
//!     for d in range(D):
//!       t1 = load(Q[m,d])
//!       t2 = load(KT[n,d])
//!       t3 += dot(t1,t2)
//!     t4 = exp(t3*(DD**-0.5))
//! ```
//!
//! Vars are renumbered `t1, t2, …` in order of first definition; a `Compute`
//! consumed exactly once by the immediately following `Accum` is inlined as
//! `t += dot(a,b)`, matching the paper's accumulate notation.

use super::{COp, Index, LoopIr, LoopKind, Stmt, VarId};
use crate::ir::func::{FuncOp, ReduceOp};
use std::collections::HashMap;
use std::fmt::Write;

pub fn render(ir: &LoopIr) -> String {
    let mut names: HashMap<VarId, String> = HashMap::new();
    let mut next = 1usize;
    let mut out = String::new();
    render_body(ir, &ir.body, 0, &mut names, &mut next, &mut out);
    out
}

fn var_name(names: &mut HashMap<VarId, String>, next: &mut usize, v: VarId) -> String {
    if let Some(n) = names.get(&v) {
        return n.clone();
    }
    let n = format!("t{next}");
    *next += 1;
    names.insert(v, n.clone());
    n
}

fn idx_str(idx: &[Index]) -> String {
    idx.iter()
        .map(|i| match i {
            Index::Iter(d) => d.name().to_lowercase(),
            Index::Zero => "0".to_string(),
        })
        .collect::<Vec<_>>()
        .join(",")
}

fn buf_ref(ir: &LoopIr, buf: usize, idx: &[Index]) -> String {
    let name = &ir.bufs[buf].name;
    if idx.is_empty() {
        name.clone()
    } else {
        format!("{name}[{}]", idx_str(idx))
    }
}

fn compute_rhs(
    op: &COp,
    args: &[VarId],
    names: &mut HashMap<VarId, String>,
    next: &mut usize,
) -> String {
    let arg_names: Vec<String> = args.iter().map(|a| var_name(names, next, *a)).collect();
    match op {
        COp::Func(FuncOp::Ew(e)) => e.render(&arg_names),
        COp::Func(f) => format!("{}({})", f.name(), arg_names.join(",")),
        COp::Misc(tag) => format!("{tag}({})", arg_names.join(",")),
    }
}

fn render_body(
    ir: &LoopIr,
    stmts: &[Stmt],
    indent: usize,
    names: &mut HashMap<VarId, String>,
    next: &mut usize,
    out: &mut String,
) {
    let pad = "  ".repeat(indent);
    let mut i = 0;
    while i < stmts.len() {
        match &stmts[i] {
            Stmt::Loop {
                kind,
                dim,
                skip_first,
                body,
                ..
            } => {
                let kw = match kind {
                    LoopKind::ForAll => "forall",
                    LoopKind::For => "for",
                };
                let range = if *skip_first {
                    format!("range(1,{})", dim.name())
                } else {
                    format!("range({})", dim.name())
                };
                let _ = writeln!(
                    out,
                    "{pad}{kw} {} in {range}:",
                    dim.name().to_lowercase()
                );
                render_body(ir, body, indent + 1, names, next, out);
            }
            Stmt::Load { var, buf, idx } => {
                let v = var_name(names, next, *var);
                let _ = writeln!(out, "{pad}{v} = load({})", buf_ref(ir, *buf, idx));
            }
            Stmt::Store { var, buf, idx } => {
                let v = var_name(names, next, *var);
                let _ = writeln!(out, "{pad}store({v}, {})", buf_ref(ir, *buf, idx));
            }
            Stmt::Compute { var, op, args } => {
                // Inline `t = f(...); acc += t` as `acc += f(...)` when the
                // computed var is used only by that Accum (paper notation).
                if let Some(Stmt::Accum {
                    var: acc,
                    op: rop,
                    src,
                }) = stmts.get(i + 1)
                {
                    if src == var && uses_of(ir, *var) == 1 {
                        let rhs = compute_rhs(op, args, names, next);
                        let a = var_name(names, next, *acc);
                        match rop {
                            ReduceOp::Add => {
                                let _ = writeln!(out, "{pad}{a} += {rhs}");
                            }
                            ReduceOp::Max => {
                                let _ = writeln!(out, "{pad}{a} = max({a}, {rhs})");
                            }
                        }
                        i += 2;
                        continue;
                    }
                }
                let rhs = compute_rhs(op, args, names, next);
                let v = var_name(names, next, *var);
                let _ = writeln!(out, "{pad}{v} = {rhs}");
            }
            Stmt::Accum { var, op, src } => {
                let s = var_name(names, next, *src);
                let v = var_name(names, next, *var);
                match op {
                    ReduceOp::Add => {
                        let _ = writeln!(out, "{pad}{v} += {s}");
                    }
                    ReduceOp::Max => {
                        let _ = writeln!(out, "{pad}{v} = max({v}, {s})");
                    }
                }
            }
            Stmt::MiscCall { tag, args, out: o } => {
                let fmt_partial = |buf: usize, idx: &[Option<Index>]| {
                    let name = &ir.bufs[buf].name;
                    if idx.is_empty() {
                        name.clone()
                    } else {
                        let parts: Vec<String> = idx
                            .iter()
                            .map(|s| match s {
                                Some(Index::Iter(d)) => d.name().to_lowercase(),
                                Some(Index::Zero) => "0".into(),
                                None => ":".into(),
                            })
                            .collect();
                        format!("{name}[{}]", parts.join(","))
                    }
                };
                let a: Vec<String> =
                    args.iter().map(|(b, i)| fmt_partial(*b, i)).collect();
                let _ = writeln!(
                    out,
                    "{pad}{} = {tag}({})",
                    fmt_partial(o.0, &o.1),
                    a.join(", ")
                );
            }
        }
        i += 1;
    }
}

/// Count reads of `var` across the whole program (for inlining decisions).
fn uses_of(ir: &LoopIr, var: VarId) -> usize {
    fn walk(stmts: &[Stmt], var: VarId, n: &mut usize) {
        for s in stmts {
            match s {
                Stmt::Loop { body, .. } => walk(body, var, n),
                Stmt::Store { var: v, .. } if *v == var => *n += 1,
                Stmt::Compute { args, .. } => {
                    *n += args.iter().filter(|a| **a == var).count()
                }
                Stmt::Accum { src, .. } if *src == var => *n += 1,
                _ => {}
            }
        }
    }
    let mut n = 0;
    walk(&ir.body, var, &mut n);
    n
}

#[cfg(test)]
mod tests {
    use crate::ir::expr::Expr;
    use crate::ir::func::{FuncOp, ReduceOp};
    use crate::ir::graph::{map_over, ArgMode, Graph};
    use crate::ir::types::Ty;
    use crate::loopir::lower::lower;

    #[test]
    fn renders_simple_map_listing() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let e = Expr::var(0).sub(Expr::cst(1.0)).div(Expr::cst(2.0));
            let r = mb.g.ew1(e, ins[0]);
            mb.collect(r);
        });
        g.output("B", o[0]);
        let s = super::render(&lower(&g));
        let want = "\
forall n in range(N):
  t1 = load(A[n])
  t2 = (t1-1)/2
  store(t2, B[n])
";
        assert_eq!(s, want);
    }

    #[test]
    fn renders_accumulate_inline() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.reduce_out(r, ReduceOp::Add);
        });
        g.output("c", o[0]);
        let s = super::render(&lower(&g));
        let want = "\
for n in range(N):
  t1 = load(A[n])
  t2 += row_sum(t1)
store(t2, c)
";
        assert_eq!(s, want);
    }

    #[test]
    fn renders_nested_with_temp_buffer() {
        // Unfused map -> reduce: the temp I1 appears.
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.collect(r);
        });
        let red = g.reduce(ReduceOp::Add, o[0]);
        g.output("c", red);
        let s = super::render(&lower(&g));
        let want = "\
forall n in range(N):
  t1 = load(A[n])
  t2 = row_sum(t1)
  store(t2, I1[n])
for n in range(N):
  t3 = load(I1[n])
  t4 += t3
store(t4, c)
";
        assert_eq!(s, want);
    }
}
