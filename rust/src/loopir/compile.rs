//! Loop-IR → flat instruction tape (the compiled execution path).
//!
//! The tree-walking interpreter ([`super::interp`]) resolves every loop
//! index through a `HashMap<Dim, usize>` and recompiles every elementwise
//! expression each time it executes — fine as a semantic ground truth,
//! far too slow to demonstrate fusion wins at realistic sizes. This pass
//! removes all of that ahead of time, in **two phases**:
//!
//! 1. [`compile_skeleton`] produces a size-independent [`TapeSkeleton`]:
//!    the flat instruction tape, loop registers, elementwise expressions
//!    pre-compiled to [`ComputeKind`], miscellaneous-op callbacks
//!    pre-resolved, buffer accesses reduced to `(register, axis)` stride
//!    terms, and a **per-loop parallel-safety annotation**
//!    ([`LoopMeta::parallel`], analyzed structurally — trip counts play
//!    no role) that marks every `forall` whose iterations the engine may
//!    fan out, whether the loop is top-level or nested under a serial
//!    loop.
//! 2. [`TapeSkeleton::bind`] specializes the skeleton to one concrete
//!    [`DimSizes`]: integer trip counts, buffer extents, and row-major
//!    stride tables. Binding is a cheap table rebuild — callers that
//!    execute one program structure under many size assignments (the
//!    autotuner's measured trials, via [`crate::exec::TapeCache`])
//!    compile the skeleton once and re-bind per trial.
//!
//! [`compile`] runs both phases back to back for one-shot callers.

use super::interp::ExecConfig;
use super::{BufId, COp, Index, LoopIr, LoopKind, Stmt, VarId};
use crate::ir::dim::{Dim, DimSizes};
use crate::ir::exprvm::{EwKernel, EwScratch};
use crate::ir::func::{FuncOp, ReduceOp};
use crate::tensor::{Mat, Val};
use std::collections::HashSet;
use std::sync::Arc;

/// Fold `src` into an accumulator (`None` = neutral-element init),
/// returning the new value and its flop charge. Like
/// [`ComputeKind::apply`], this is the single shared implementation of
/// `Accum` numerics and accounting for both backends — keeping them
/// bit-identical by construction.
pub fn accum_val(acc: Option<&Val>, op: ReduceOp, src: Arc<Val>) -> (Arc<Val>, u64) {
    match (acc, op) {
        (None, _) => (src, 0),
        (Some(a), ReduceOp::Add) => {
            let fl = (src.bytes() / 4) as u64;
            (Arc::new(a.add(&src)), fl)
        }
        (Some(a), ReduceOp::Max) => (Arc::new(a.zip(&src, f32::max)), 0),
    }
}

/// Index of a loop register in the machine's register file.
pub type Reg = usize;

/// A precomputed buffer access: `flat = Σ regs[r] · stride`.
/// (`Index::Zero` slots contribute nothing and are dropped at compile time.)
#[derive(Clone, Debug, Default)]
pub struct Access {
    pub terms: Vec<(Reg, usize)>,
}

impl Access {
    #[inline]
    pub fn flat(&self, regs: &[usize]) -> usize {
        let mut f = 0;
        for &(r, s) in &self.terms {
            f += regs[r] * s;
        }
        f
    }
}

/// Everything the machine needs to drive one loop site.
#[derive(Clone, Debug)]
pub struct LoopMeta {
    pub reg: Reg,
    /// First iteration (1 for Rule 7's `skip_first`).
    pub start: usize,
    /// Trip count (the dim's block count).
    pub trip: usize,
    /// Instruction index of the first body instruction.
    pub body_ip: usize,
    /// Instruction index of this loop's `LoopEnd`.
    pub end_ip: usize,
    /// Vars reset at the top of every iteration (from [`Stmt::Loop`]).
    pub clears: Vec<VarId>,
    /// This `forall`'s iterations passed the parallel-safety analysis:
    /// the engine may run them concurrently (fanning out at the
    /// outermost such loop it reaches on the main thread).
    pub parallel: bool,
    /// Tape instructions executed by one full run of this loop (bound
    /// trip counts of nested loops folded in) — the engine's cost proxy
    /// for whether a nested fan-out is worth a thread-scope spawn.
    pub weight: u64,
}

/// One slot of a (possibly partial) miscellaneous-call buffer index.
#[derive(Clone, Debug)]
pub enum SlotSel {
    /// Bound by an enclosing loop register.
    Reg(Reg),
    /// A fixed coordinate (`Index::Zero`).
    Fixed(usize),
    /// Ranges over the whole dim; payload is the extent.
    All(usize),
}

/// A whole-array miscellaneous operator call, callback pre-resolved.
#[derive(Clone)]
pub struct MiscSite {
    pub tag: String,
    pub f: fn(&[Vec<Val>]) -> Vec<Val>,
    pub args: Vec<(BufId, Vec<SlotSel>)>,
    pub out: (BufId, Vec<SlotSel>),
}

// manual impl: Debug is not derivable over higher-ranked fn pointers on
// older toolchains
impl std::fmt::Debug for MiscSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MiscSite")
            .field("tag", &self.tag)
            .field("args", &self.args)
            .field("out", &self.out)
            .finish()
    }
}

/// A compute site: argument vars plus the pre-resolved operator kind.
#[derive(Clone, Debug)]
pub struct ComputeSite {
    pub args: Vec<VarId>,
    pub kind: ComputeKind,
}

/// A block operator with all name/param resolution done ahead of time.
/// Shared by both backends: the interpreter builds one per execution (its
/// naive baseline behavior), the compiled engine builds one per site.
///
/// `Ew` carries an [`EwKernel`]: the scalar postfix tape *and* its
/// batched vector program, compiled together at resolution time, so
/// vector/block applications run one VM pass per value instead of one
/// stack-machine round-trip per element.
#[derive(Clone)]
pub enum ComputeKind {
    Add,
    Mul,
    RowShift,
    RowScale,
    RowSum,
    Dot,
    Outer,
    Ew(EwKernel),
    Misc(fn(&[Val]) -> Val),
}

impl std::fmt::Debug for ComputeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ComputeKind::Add => f.write_str("Add"),
            ComputeKind::Mul => f.write_str("Mul"),
            ComputeKind::RowShift => f.write_str("RowShift"),
            ComputeKind::RowScale => f.write_str("RowScale"),
            ComputeKind::RowSum => f.write_str("RowSum"),
            ComputeKind::Dot => f.write_str("Dot"),
            ComputeKind::Outer => f.write_str("Outer"),
            ComputeKind::Ew(ce) => f.debug_tuple("Ew").field(ce).finish(),
            ComputeKind::Misc(_) => f.write_str("Misc(<fn>)"),
        }
    }
}

impl ComputeKind {
    /// Resolve an op against the config's params and misc registry.
    pub fn from_op(op: &COp, cfg: &ExecConfig) -> ComputeKind {
        match op {
            COp::Func(FuncOp::Add) => ComputeKind::Add,
            COp::Func(FuncOp::Mul) => ComputeKind::Mul,
            COp::Func(FuncOp::RowShift) => ComputeKind::RowShift,
            COp::Func(FuncOp::RowScale) => ComputeKind::RowScale,
            COp::Func(FuncOp::RowSum) => ComputeKind::RowSum,
            COp::Func(FuncOp::Dot) => ComputeKind::Dot,
            COp::Func(FuncOp::Outer) => ComputeKind::Outer,
            COp::Func(FuncOp::Ew(e)) => ComputeKind::Ew(EwKernel::new(e.compile(&cfg.params))),
            COp::Misc(tag) => ComputeKind::Misc(
                *cfg.misc_ops
                    .get(tag)
                    .unwrap_or_else(|| panic!("no misc-op callback registered for {tag}")),
            ),
        }
    }

    /// Apply to local values; returns the result and its flop charge.
    /// This is the single source of truth for block-op numerics *and*
    /// flop accounting — both backends route through it, which is what
    /// makes their outputs and `MemSim.flops` bit-identical. `scratch`
    /// is the caller's reusable elementwise workspace (scalar stack +
    /// VM slab file).
    pub fn apply(&self, args: &[&Val], scratch: &mut EwScratch) -> (Val, u64) {
        match self {
            ComputeKind::Add => {
                let v = args[0].add(args[1]);
                let fl = (v.bytes() / 4) as u64;
                (v, fl)
            }
            ComputeKind::Mul => {
                let v = args[0].mul(args[1]);
                let fl = (v.bytes() / 4) as u64;
                (v, fl)
            }
            ComputeKind::RowShift => {
                let m = args[0].as_block();
                let c = args[1].as_vector();
                (Val::Block(m.row_shift(c)), (m.rows * m.cols) as u64)
            }
            ComputeKind::RowScale => {
                let m = args[0].as_block();
                let c = args[1].as_vector();
                (Val::Block(m.row_scale(c)), (m.rows * m.cols) as u64)
            }
            ComputeKind::RowSum => {
                let m = args[0].as_block();
                (Val::Vector(m.row_sum()), (m.rows * m.cols) as u64)
            }
            ComputeKind::Dot => {
                let a = args[0].as_block();
                let b = args[1].as_block();
                let v = a.dot_bt(b);
                let fl = 2 * (a.rows * a.cols * b.rows) as u64;
                (Val::Block(v), fl)
            }
            ComputeKind::Outer => {
                let a = args[0].as_vector();
                let b = args[1].as_vector();
                (Val::Block(Mat::outer(a, b)), (a.len() * b.len()) as u64)
            }
            ComputeKind::Ew(kern) => {
                let n = kern.expr.arity;
                assert_eq!(args.len(), n, "ew arity mismatch");
                let first = args
                    .first()
                    .unwrap_or_else(|| panic!("ew with no inputs has no output shape"));
                // argument marshalling: a fixed stack array up to arity 8
                // (the common case), a heap allocation beyond — no arity
                // cap (regression-tested at arity 9).
                let v = match first {
                    Val::Scalar(_) => {
                        let mut small = [0.0f32; 8];
                        let mut big: Vec<f32>;
                        let xs: &mut [f32] = if n <= 8 {
                            &mut small[..n]
                        } else {
                            big = vec![0.0; n];
                            &mut big
                        };
                        for (k, a) in args.iter().enumerate() {
                            xs[k] = a.as_scalar();
                        }
                        Val::Scalar(kern.expr.eval_with(xs, &mut scratch.stack))
                    }
                    // vectors and blocks run the batched VM: one slice
                    // program per value instead of one stack-machine
                    // round-trip per element, bit-identical by the
                    // exprvm contract
                    Val::Vector(v0) => {
                        let mut out = vec![0.0f32; v0.len()];
                        let mut small: [&[f32]; 8] = [&[]; 8];
                        let big: Vec<&[f32]>;
                        let slices: &[&[f32]] = if n <= 8 {
                            for (k, a) in args.iter().enumerate() {
                                small[k] = a.as_vector();
                            }
                            &small[..n]
                        } else {
                            big = args.iter().map(|a| a.as_vector()).collect();
                            &big
                        };
                        kern.vm.run(slices, &mut out, scratch);
                        Val::Vector(out)
                    }
                    Val::Block(m0) => {
                        let mut out = Mat::zeros(m0.rows, m0.cols);
                        let mut small: [&[f32]; 8] = [&[]; 8];
                        let big: Vec<&[f32]>;
                        let slices: &[&[f32]] = if n <= 8 {
                            for (k, a) in args.iter().enumerate() {
                                small[k] = &a.as_block().data;
                            }
                            &small[..n]
                        } else {
                            big = args.iter().map(|a| &a.as_block().data[..]).collect();
                            &big
                        };
                        kern.vm.run(slices, &mut out.data, scratch);
                        Val::Block(out)
                    }
                };
                let fl = (v.bytes() / 4) as u64;
                (v, fl)
            }
            ComputeKind::Misc(f) => {
                let owned: Vec<Val> = args.iter().map(|v| (*v).clone()).collect();
                (f(&owned), 0)
            }
        }
    }
}

/// One flat-tape instruction. Control flow is two ip-jumps per loop
/// iteration; everything else indexes side tables by small integers.
///
/// `Fused(site)` is produced only by [`specialize_skeleton`]: the
/// payload indexes [`TapeSkeleton::fused`] / [`CompiledProgram::fused`],
/// and the engine hands the whole site to one pre-monomorphized kernel
/// body ([`crate::exec::kernels`]) instead of interpreting it
/// instruction by instruction.
#[derive(Clone, Debug)]
pub enum Instr {
    LoopBegin(usize),
    LoopEnd(usize),
    Load { var: VarId, buf: BufId, acc: usize },
    Store { var: VarId, buf: BufId, acc: usize },
    Compute { var: VarId, site: usize },
    Accum { var: VarId, op: ReduceOp, src: VarId },
    Misc(usize),
    Fused(usize),
}

/// A buffer with dims resolved to concrete extents and row-major strides.
#[derive(Clone, Debug)]
pub struct BufMeta {
    pub name: String,
    pub dims: Vec<usize>,
    pub strides: Vec<usize>,
    pub is_input: bool,
    pub is_output: bool,
}

/// One top-level statement of the program: its instruction range and
/// whether it counts as a kernel launch. (Which loops may fan out is a
/// per-loop property now — see [`LoopMeta::parallel`].)
#[derive(Clone, Debug)]
pub struct TopRange {
    pub ips: (usize, usize),
    pub kernel: bool,
}

// ---------------------------------------------------------------------------
// Kernel specialization (the `Specialized` backend's bind-time pass)
// ---------------------------------------------------------------------------

/// Which pre-monomorphized fused loop body executes a [`FusedSite`].
/// Classified once by [`specialize_skeleton`]; the engine resolves the
/// id to a concrete `fn` in the [`crate::exec::kernels`] registry — no
/// per-instruction dispatch remains inside the site.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum KernelId {
    /// A serial contraction loop whose body is exactly
    /// `load a; load b; t = dot(a, b); acc += t` — the `dot_bt`
    /// micro-kernel with its accumulate folded in.
    DotAcc,
    /// Flash attention's inner softmax·V nest: a serial loop containing
    /// a [`KernelId::DotAcc`] child (the QKᵀ contraction) plus the
    /// exp/row-sum/·V epilogue, accumulated across key blocks without
    /// materializing the score matrix.
    FlashInner,
    /// Any other all-straight-line serial loop nest, driven by the
    /// generic pre-compiled step walker.
    SerialNest,
    /// A straight-line load→compute→store run inside a non-collapsible
    /// (parallel or misc-bearing) loop body, executed as one unit.
    StreamRun,
}

impl KernelId {
    pub fn name(&self) -> &'static str {
        match self {
            KernelId::DotAcc => "dot_acc",
            KernelId::FlashInner => "flash_inner",
            KernelId::SerialNest => "serial_nest",
            KernelId::StreamRun => "stream_run",
        }
    }
}

/// One step of a fused site — the same payloads as the matching
/// [`Instr`] arms, pre-extracted so kernel bodies index side tables
/// without re-matching the instruction encoding.
#[derive(Clone, Debug)]
pub enum FusedStep {
    Load { var: VarId, buf: BufId, acc: usize },
    Store { var: VarId, buf: BufId, acc: usize },
    Compute { var: VarId, site: usize },
    Accum { var: VarId, op: ReduceOp, src: VarId },
    /// A nested fused loop, by index into the `fused` table.
    Loop(usize),
}

/// A region of the tape committed to one kernel body at specialization
/// time. Two flavors: a *loop site* (`loop_id: Some`) replaces an
/// entire serial `LoopBegin..LoopEnd` nest — the kernel drives the
/// loop itself (register, clears, iterations); a *run site*
/// (`loop_id: None`) wraps a straight-line instruction run inside a
/// loop that could not be collapsed, executed once each time reached.
#[derive(Clone, Debug)]
pub struct FusedSite {
    /// `Some(loop_id)` for a collapsed loop, `None` for a run site.
    pub loop_id: Option<usize>,
    pub steps: Vec<FusedStep>,
    pub kernel: KernelId,
}

/// Per-skeleton record of what [`specialize_skeleton`] matched — the
/// observable coverage the CLI surfaces (`specialization: X/Y nests
/// fused`), so unmatched patterns are visible instead of silently
/// interpreted.
#[derive(Clone, Debug, Default)]
pub struct SpecReport {
    /// Loop nests in the skeleton.
    pub total_nests: usize,
    /// Nests executing entirely through fused kernel bodies: collapsed
    /// outright, or with every body instruction fused (counted
    /// bottom-up, so a parallel grid whose whole body is one run site
    /// counts).
    pub fused_nests: usize,
    /// Matched sites per kernel body.
    pub by_kernel: std::collections::BTreeMap<&'static str, usize>,
}

/// A fully lowered, ready-to-execute program.
#[derive(Clone, Debug)]
pub struct CompiledProgram {
    pub instrs: Vec<Instr>,
    pub loops: Vec<LoopMeta>,
    pub accesses: Vec<Access>,
    pub computes: Vec<ComputeSite>,
    pub miscs: Vec<MiscSite>,
    pub bufs: Vec<BufMeta>,
    pub tops: Vec<TopRange>,
    /// Fused-site table (empty unless the skeleton was specialized).
    pub fused: Vec<FusedSite>,
    pub n_vars: usize,
    pub n_regs: usize,
}

impl CompiledProgram {
    /// Grid loops the engine is allowed to run multi-threaded (top-level
    /// or nested).
    pub fn parallel_grid_loops(&self) -> usize {
        self.loops.iter().filter(|l| l.parallel).count()
    }
}

// ---------------------------------------------------------------------------
// Size-independent skeleton
// ---------------------------------------------------------------------------

/// A loop site before sizes are known: the trip count is still a [`Dim`].
#[derive(Clone, Debug)]
pub struct SymLoop {
    pub reg: Reg,
    pub dim: Dim,
    pub start: usize,
    pub body_ip: usize,
    pub end_ip: usize,
    pub clears: Vec<VarId>,
    pub parallel: bool,
}

/// A buffer access before sizes are known: `(register, buffer axis)`
/// terms; the axis stride is looked up at bind time.
#[derive(Clone, Debug)]
pub struct SymAccess {
    pub buf: BufId,
    pub terms: Vec<(Reg, usize)>,
}

/// A miscellaneous-call index slot before sizes are known.
#[derive(Clone, Debug)]
pub enum SymSlot {
    Reg(Reg),
    Fixed(usize),
    /// Ranges over the whole axis; the extent is bound per `DimSizes`.
    All,
}

/// A miscellaneous-call site before sizes are known.
#[derive(Clone)]
pub struct SymMisc {
    pub tag: String,
    pub f: fn(&[Vec<Val>]) -> Vec<Val>,
    pub args: Vec<(BufId, Vec<SymSlot>)>,
    pub out: (BufId, Vec<SymSlot>),
}

impl std::fmt::Debug for SymMisc {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SymMisc")
            .field("tag", &self.tag)
            .field("args", &self.args)
            .field("out", &self.out)
            .finish()
    }
}

/// A buffer declaration before sizes are known.
#[derive(Clone, Debug)]
pub struct SymBuf {
    pub name: String,
    pub dims: Vec<Dim>,
    pub is_input: bool,
    pub is_output: bool,
}

/// The size-independent product of phase 1: everything in a
/// [`CompiledProgram`] except trip counts, buffer extents, and stride
/// tables. Immutable and shareable (`Arc`) across threads and autotune
/// trials; see [`crate::exec::TapeCache`].
#[derive(Clone, Debug)]
pub struct TapeSkeleton {
    pub instrs: Vec<Instr>,
    pub loops: Vec<SymLoop>,
    pub accesses: Vec<SymAccess>,
    pub computes: Vec<ComputeSite>,
    pub miscs: Vec<SymMisc>,
    pub bufs: Vec<SymBuf>,
    pub tops: Vec<TopRange>,
    /// Fused-site table; empty until [`specialize_skeleton`] runs.
    pub fused: Vec<FusedSite>,
    /// Coverage record; `Some` iff the skeleton was specialized.
    pub spec: Option<SpecReport>,
    pub n_vars: usize,
    pub n_regs: usize,
}

fn bind_slots(sels: &[SymSlot], buf: &BufMeta) -> Vec<SlotSel> {
    sels.iter()
        .enumerate()
        .map(|(i, s)| match s {
            SymSlot::Reg(r) => SlotSel::Reg(*r),
            SymSlot::Fixed(c) => SlotSel::Fixed(*c),
            SymSlot::All => SlotSel::All(buf.dims[i]),
        })
        .collect()
}

impl TapeSkeleton {
    /// Phase 2: specialize to one concrete size assignment. Only trip
    /// counts, buffer extents, and stride tables are computed here — the
    /// tape, operator resolution, and parallel annotations carry over.
    pub fn bind(&self, sizes: &DimSizes) -> CompiledProgram {
        let bufs: Vec<BufMeta> = self
            .bufs
            .iter()
            .map(|b| {
                let dims: Vec<usize> = b.dims.iter().map(|d| sizes.get(d)).collect();
                let mut strides = vec![1usize; dims.len()];
                for i in (0..dims.len().saturating_sub(1)).rev() {
                    strides[i] = strides[i + 1] * dims[i + 1];
                }
                BufMeta {
                    name: b.name.clone(),
                    dims,
                    strides,
                    is_input: b.is_input,
                    is_output: b.is_output,
                }
            })
            .collect();
        let accesses: Vec<Access> = self
            .accesses
            .iter()
            .map(|a| Access {
                terms: a
                    .terms
                    .iter()
                    .map(|&(r, axis)| (r, bufs[a.buf].strides[axis]))
                    .collect(),
            })
            .collect();
        let mut loops: Vec<LoopMeta> = self
            .loops
            .iter()
            .map(|l| LoopMeta {
                reg: l.reg,
                start: l.start,
                trip: sizes.get(&l.dim),
                body_ip: l.body_ip,
                end_ip: l.end_ip,
                clears: l.clears.clone(),
                parallel: l.parallel,
                weight: 0,
            })
            .collect();
        // Executed-instruction weights, inner loops first (a nested loop
        // always has a higher index than its parent, so reverse order
        // has every inner weight ready when its parent sums the body).
        //
        // A `Fused` site must charge exactly what the instructions it
        // replaced would have charged — `LoopMeta::weight` gates the
        // engine's nested fan-out decision, so any drift here would
        // change scheduling (and `peak_local_bytes`) between the
        // compiled and specialized backends. `fused_weight` mirrors the
        // original recursion: a loop site is `iters · max(1, Σ steps)`,
        // a run site is just `Σ steps`.
        fn fused_weight(site: &FusedSite, fused: &[FusedSite], loops: &[LoopMeta]) -> u64 {
            let mut cost = 0u64;
            for st in &site.steps {
                cost += match st {
                    FusedStep::Loop(child) => fused_weight(&fused[*child], fused, loops),
                    _ => 1,
                };
            }
            match site.loop_id {
                Some(li) => {
                    let iters = loops[li].trip.saturating_sub(loops[li].start) as u64;
                    iters * cost.max(1)
                }
                None => cost,
            }
        }
        // Loops collapsed into a fused site no longer appear in the
        // instruction tape (their body_ip/end_ip are poisoned); their
        // weight comes from the site instead.
        let mut site_of_loop = vec![usize::MAX; loops.len()];
        for (fi, site) in self.fused.iter().enumerate() {
            if let Some(li) = site.loop_id {
                site_of_loop[li] = fi;
            }
        }
        let mut weights = vec![0u64; loops.len()];
        for li in (0..loops.len()).rev() {
            if site_of_loop[li] != usize::MAX {
                weights[li] = fused_weight(&self.fused[site_of_loop[li]], &self.fused, &loops);
                continue;
            }
            let mut cost = 0u64;
            let mut ip = loops[li].body_ip;
            while ip < loops[li].end_ip {
                match &self.instrs[ip] {
                    Instr::LoopBegin(lj) => {
                        cost += weights[*lj];
                        ip = loops[*lj].end_ip + 1;
                    }
                    Instr::Fused(fi) => {
                        cost += fused_weight(&self.fused[*fi], &self.fused, &loops);
                        ip += 1;
                    }
                    _ => {
                        cost += 1;
                        ip += 1;
                    }
                }
            }
            let iters = loops[li].trip.saturating_sub(loops[li].start) as u64;
            weights[li] = iters * cost.max(1);
        }
        for (l, w) in loops.iter_mut().zip(&weights) {
            l.weight = *w;
        }
        let miscs: Vec<MiscSite> = self
            .miscs
            .iter()
            .map(|ms| MiscSite {
                tag: ms.tag.clone(),
                f: ms.f,
                args: ms
                    .args
                    .iter()
                    .map(|(b, sels)| (*b, bind_slots(sels, &bufs[*b])))
                    .collect(),
                out: (ms.out.0, bind_slots(&ms.out.1, &bufs[ms.out.0])),
            })
            .collect();
        CompiledProgram {
            instrs: self.instrs.clone(),
            loops,
            accesses,
            computes: self.computes.clone(),
            miscs,
            bufs,
            tops: self.tops.clone(),
            fused: self.fused.clone(),
            n_vars: self.n_vars,
            n_regs: self.n_regs,
        }
    }
}

/// Flatten `ir` against the concrete `cfg` (sizes, params, misc registry):
/// both phases back to back.
pub fn compile(ir: &LoopIr, cfg: &ExecConfig) -> CompiledProgram {
    compile_skeleton(ir, cfg).bind(&cfg.sizes)
}

/// Phase 1: build the size-independent tape skeleton (see module docs).
/// Uses `cfg` only for scalar params and the misc-op registries — never
/// `cfg.sizes`.
pub fn compile_skeleton(ir: &LoopIr, cfg: &ExecConfig) -> TapeSkeleton {
    let bufs: Vec<SymBuf> = ir
        .bufs
        .iter()
        .map(|d| SymBuf {
            name: d.name.clone(),
            dims: d.dims.clone(),
            is_input: d.is_input,
            is_output: d.is_output,
        })
        .collect();

    let mut c = Compiler {
        cfg,
        bufs,
        instrs: Vec::new(),
        loops: Vec::new(),
        accesses: Vec::new(),
        computes: Vec::new(),
        miscs: Vec::new(),
        scope: Vec::new(),
    };

    let mut tops = Vec::new();
    for s in &ir.body {
        let start = c.instrs.len();
        c.stmt(s);
        let end = c.instrs.len();
        tops.push(TopRange {
            ips: (start, end),
            kernel: matches!(s, Stmt::Loop { .. }),
        });
    }

    let n_regs = c.loops.len();
    TapeSkeleton {
        instrs: c.instrs,
        loops: c.loops,
        accesses: c.accesses,
        computes: c.computes,
        miscs: c.miscs,
        bufs: c.bufs,
        tops,
        fused: Vec::new(),
        spec: None,
        n_vars: ir.n_vars,
        n_regs,
    }
}

struct Compiler<'a> {
    cfg: &'a ExecConfig,
    bufs: Vec<SymBuf>,
    instrs: Vec<Instr>,
    loops: Vec<SymLoop>,
    accesses: Vec<SymAccess>,
    computes: Vec<ComputeSite>,
    miscs: Vec<SymMisc>,
    /// Enclosing loops, innermost last: (dim, register).
    scope: Vec<(Dim, Reg)>,
}

impl<'a> Compiler<'a> {
    fn lookup(&self, d: &Dim) -> Reg {
        self.scope
            .iter()
            .rev()
            .find(|(sd, _)| sd == d)
            .map(|(_, r)| *r)
            .unwrap_or_else(|| panic!("compile: no enclosing loop over {d}"))
    }

    fn access(&mut self, buf: BufId, idx: &[Index]) -> usize {
        assert_eq!(
            idx.len(),
            self.bufs[buf].dims.len(),
            "access rank mismatch on buffer {}",
            self.bufs[buf].name
        );
        let mut terms = Vec::new();
        for (i, ix) in idx.iter().enumerate() {
            match ix {
                Index::Iter(d) => {
                    let reg = self.lookup(d);
                    terms.push((reg, i));
                }
                Index::Zero => {}
            }
        }
        self.accesses.push(SymAccess { buf, terms });
        self.accesses.len() - 1
    }

    fn slot_sels(&self, idx: &[Option<Index>]) -> Vec<SymSlot> {
        idx.iter()
            .map(|s| match s {
                Some(Index::Iter(d)) => SymSlot::Reg(self.lookup(d)),
                Some(Index::Zero) => SymSlot::Fixed(0),
                None => SymSlot::All,
            })
            .collect()
    }

    fn stmt(&mut self, s: &Stmt) {
        match s {
            Stmt::Loop {
                kind,
                dim,
                skip_first,
                body,
                clears,
            } => {
                let parallel = *kind == LoopKind::ForAll && loop_is_parallel(dim, body);
                let loop_id = self.loops.len();
                self.loops.push(SymLoop {
                    reg: loop_id,
                    dim: dim.clone(),
                    start: usize::from(*skip_first),
                    body_ip: 0,
                    end_ip: 0,
                    clears: clears.clone(),
                    parallel,
                });
                let begin_ip = self.instrs.len();
                self.instrs.push(Instr::LoopBegin(loop_id));
                self.scope.push((dim.clone(), loop_id));
                for st in body {
                    self.stmt(st);
                }
                self.scope.pop();
                let end_ip = self.instrs.len();
                self.instrs.push(Instr::LoopEnd(loop_id));
                self.loops[loop_id].body_ip = begin_ip + 1;
                self.loops[loop_id].end_ip = end_ip;
            }
            Stmt::Load { var, buf, idx } => {
                let acc = self.access(*buf, idx);
                self.instrs.push(Instr::Load {
                    var: *var,
                    buf: *buf,
                    acc,
                });
            }
            Stmt::Store { var, buf, idx } => {
                let acc = self.access(*buf, idx);
                self.instrs.push(Instr::Store {
                    var: *var,
                    buf: *buf,
                    acc,
                });
            }
            Stmt::Compute { var, op, args } => {
                let kind = ComputeKind::from_op(op, self.cfg);
                self.computes.push(ComputeSite {
                    args: args.clone(),
                    kind,
                });
                self.instrs.push(Instr::Compute {
                    var: *var,
                    site: self.computes.len() - 1,
                });
            }
            Stmt::Accum { var, op, src } => {
                self.instrs.push(Instr::Accum {
                    var: *var,
                    op: *op,
                    src: *src,
                });
            }
            Stmt::MiscCall { tag, args, out } => {
                let f = *self
                    .cfg
                    .misc_list_ops
                    .get(tag)
                    .unwrap_or_else(|| panic!("no whole-array misc-op registered for {tag}"));
                let site = SymMisc {
                    tag: tag.clone(),
                    f,
                    args: args
                        .iter()
                        .map(|(b, idx)| (*b, self.slot_sels(idx)))
                        .collect(),
                    out: (out.0, self.slot_sels(&out.1)),
                };
                self.miscs.push(site);
                self.instrs.push(Instr::Misc(self.miscs.len() - 1));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Kernel-specialization pass
// ---------------------------------------------------------------------------

/// Rewrite a skeleton so that recognized instruction regions execute
/// through pre-monomorphized kernel bodies ([`crate::exec::kernels`])
/// instead of the generic per-instruction interpreter loop. Dispatch is
/// thereby resolved **once, here** — not per element at run time.
///
/// Two patterns are committed:
///
/// * **Loop sites** — a serial (non-`parallel`) nested loop whose body
///   is pure straight-line tape (loads, stores, computes, accums) plus
///   wholly-fusible child loops collapses into a single
///   [`Instr::Fused`]; the kernel body drives the loop itself.
///   Parallel loops are never collapsed (the engine's fan-out,
///   work-stealing, and slice attribution hang off their
///   `LoopBegin`), and neither are top-level loops (the stacked-launch
///   slice path requires a literal top `LoopBegin`).
/// * **Run sites** — inside any loop that could not collapse, each
///   maximal straight-line run of two or more fusible instructions
///   (runs break at `Misc`) is wrapped into one [`Instr::Fused`]
///   executed per arrival.
///
/// The pass preserves the cardinal invariant by construction: kernel
/// bodies replay the exact primitive sequence (same [`ComputeKind`]
/// numerics, same `MemSim` charges, same set/clear order), loop-table
/// indices are never renumbered (registers and accesses keep meaning),
/// and [`TapeSkeleton::bind`] charges fused regions the same
/// `LoopMeta::weight` the original instructions carried, so nested
/// fan-out decisions are unchanged. Collapsed loops keep their
/// [`SymLoop`] entry but have `body_ip`/`end_ip` poisoned to
/// `usize::MAX` — any stale use panics instead of misreading the tape.
///
/// The match outcome is recorded in [`TapeSkeleton::spec`] so coverage
/// is observable. Specializing an already-specialized skeleton is an
/// identity.
pub fn specialize_skeleton(skel: &TapeSkeleton) -> TapeSkeleton {
    if skel.spec.is_some() {
        return skel.clone();
    }
    let mut out = skel.clone();
    let mut instrs: Vec<Instr> = Vec::with_capacity(skel.instrs.len());
    let mut fused: Vec<FusedSite> = Vec::new();
    let mut tops: Vec<TopRange> = Vec::with_capacity(skel.tops.len());
    for top in &skel.tops {
        let start = instrs.len();
        let mut ip = top.ips.0;
        while ip < top.ips.1 {
            match &skel.instrs[ip] {
                Instr::LoopBegin(li) => {
                    // Top-level loops always keep their LoopBegin; only
                    // their bodies specialize.
                    instrs.push(Instr::LoopBegin(*li));
                    spec_body(
                        skel,
                        skel.loops[*li].body_ip,
                        skel.loops[*li].end_ip,
                        &mut instrs,
                        &mut fused,
                    );
                    instrs.push(Instr::LoopEnd(*li));
                    ip = skel.loops[*li].end_ip + 1;
                }
                other => {
                    instrs.push(other.clone());
                    ip += 1;
                }
            }
        }
        tops.push(TopRange {
            ips: (start, instrs.len()),
            kernel: top.kernel,
        });
    }
    // Re-point every surviving loop at its new instruction range;
    // poison the collapsed ones.
    for l in &mut out.loops {
        l.body_ip = usize::MAX;
        l.end_ip = usize::MAX;
    }
    for (ip, ins) in instrs.iter().enumerate() {
        match ins {
            Instr::LoopBegin(li) => out.loops[*li].body_ip = ip + 1,
            Instr::LoopEnd(li) => out.loops[*li].end_ip = ip,
            _ => {}
        }
    }
    out.spec = Some(spec_report(&out.loops, &instrs, &fused));
    out.instrs = instrs;
    out.tops = tops;
    out.fused = fused;
    out
}

/// Specialize one loop body `[lo, hi)`: collapse fusible child loops,
/// wrap straight-line runs, pass everything else through.
fn spec_body(
    skel: &TapeSkeleton,
    lo: usize,
    hi: usize,
    instrs: &mut Vec<Instr>,
    fused: &mut Vec<FusedSite>,
) {
    // The pending straight-line run: the step plus the instruction to
    // re-emit verbatim if the run ends up shorter than two.
    let mut run: Vec<(FusedStep, Instr)> = Vec::new();
    fn flush(
        run: &mut Vec<(FusedStep, Instr)>,
        instrs: &mut Vec<Instr>,
        fused: &mut Vec<FusedSite>,
    ) {
        if run.len() >= 2 {
            let steps: Vec<FusedStep> = run.drain(..).map(|(s, _)| s).collect();
            fused.push(FusedSite {
                loop_id: None,
                kernel: KernelId::StreamRun,
                steps,
            });
            instrs.push(Instr::Fused(fused.len() - 1));
        } else {
            for (_, ins) in run.drain(..) {
                instrs.push(ins);
            }
        }
    }
    let mut ip = lo;
    while ip < hi {
        match &skel.instrs[ip] {
            Instr::LoopBegin(li) => {
                if loop_fusible(skel, *li) {
                    let site = build_site(skel, *li, fused);
                    run.push((FusedStep::Loop(site), Instr::Fused(site)));
                } else {
                    flush(&mut run, instrs, fused);
                    instrs.push(Instr::LoopBegin(*li));
                    spec_body(skel, skel.loops[*li].body_ip, skel.loops[*li].end_ip, instrs, fused);
                    instrs.push(Instr::LoopEnd(*li));
                }
                ip = skel.loops[*li].end_ip + 1;
            }
            Instr::Load { var, buf, acc } => {
                run.push((
                    FusedStep::Load { var: *var, buf: *buf, acc: *acc },
                    skel.instrs[ip].clone(),
                ));
                ip += 1;
            }
            Instr::Store { var, buf, acc } => {
                run.push((
                    FusedStep::Store { var: *var, buf: *buf, acc: *acc },
                    skel.instrs[ip].clone(),
                ));
                ip += 1;
            }
            Instr::Compute { var, site } => {
                run.push((
                    FusedStep::Compute { var: *var, site: *site },
                    skel.instrs[ip].clone(),
                ));
                ip += 1;
            }
            Instr::Accum { var, op, src } => {
                run.push((
                    FusedStep::Accum { var: *var, op: *op, src: *src },
                    skel.instrs[ip].clone(),
                ));
                ip += 1;
            }
            other => {
                // Misc (or a pre-existing Fused): breaks the run.
                flush(&mut run, instrs, fused);
                instrs.push(other.clone());
                ip += 1;
            }
        }
    }
    flush(&mut run, instrs, fused);
}

/// Can loop `li` collapse into a single fused site? Serial only, and
/// its body must be straight-line tape plus recursively-fusible child
/// loops — nothing the kernel bodies cannot replay.
fn loop_fusible(skel: &TapeSkeleton, li: usize) -> bool {
    if skel.loops[li].parallel {
        return false;
    }
    let mut ip = skel.loops[li].body_ip;
    while ip < skel.loops[li].end_ip {
        match &skel.instrs[ip] {
            Instr::LoopBegin(lj) => {
                if !loop_fusible(skel, *lj) {
                    return false;
                }
                ip = skel.loops[*lj].end_ip + 1;
            }
            Instr::Load { .. }
            | Instr::Store { .. }
            | Instr::Compute { .. }
            | Instr::Accum { .. } => {
                ip += 1;
            }
            _ => return false,
        }
    }
    true
}

/// Build the fused site for a loop [`loop_fusible`] accepted —
/// infallible by that precondition, so no partially-built sites are
/// ever left behind. Children are built depth-first, so a child site
/// always has a lower index than its parent.
fn build_site(skel: &TapeSkeleton, li: usize, fused: &mut Vec<FusedSite>) -> usize {
    let mut steps = Vec::new();
    let mut ip = skel.loops[li].body_ip;
    while ip < skel.loops[li].end_ip {
        match &skel.instrs[ip] {
            Instr::LoopBegin(lj) => {
                let child = build_site(skel, *lj, fused);
                steps.push(FusedStep::Loop(child));
                ip = skel.loops[*lj].end_ip + 1;
            }
            Instr::Load { var, buf, acc } => {
                steps.push(FusedStep::Load { var: *var, buf: *buf, acc: *acc });
                ip += 1;
            }
            Instr::Store { var, buf, acc } => {
                steps.push(FusedStep::Store { var: *var, buf: *buf, acc: *acc });
                ip += 1;
            }
            Instr::Compute { var, site } => {
                steps.push(FusedStep::Compute { var: *var, site: *site });
                ip += 1;
            }
            Instr::Accum { var, op, src } => {
                steps.push(FusedStep::Accum { var: *var, op: *op, src: *src });
                ip += 1;
            }
            other => unreachable!("loop_fusible admitted {other:?}"),
        }
    }
    let kernel = classify_loop_site(skel, &steps, fused);
    fused.push(FusedSite {
        loop_id: Some(li),
        steps,
        kernel,
    });
    fused.len() - 1
}

/// Pattern table for collapsed loops. Anything unmatched falls back to
/// the generic [`KernelId::SerialNest`] walker — still one fused site,
/// just without a bespoke body.
fn classify_loop_site(skel: &TapeSkeleton, steps: &[FusedStep], fused: &[FusedSite]) -> KernelId {
    // dot_acc: load a; load b; t = dot(a, b); acc += t
    if let [
        FusedStep::Load { var: a, .. },
        FusedStep::Load { var: b, .. },
        FusedStep::Compute { var: t, site },
        FusedStep::Accum { op: ReduceOp::Add, src, .. },
    ] = steps
    {
        if matches!(skel.computes[*site].kind, ComputeKind::Dot)
            && skel.computes[*site].args == [*a, *b]
            && a != b
            && src == t
        {
            return KernelId::DotAcc;
        }
    }
    // flash_inner: a serial loop hosting a dot_acc child (the QKᵀ
    // contraction) and at least two accumulators (the softmax row-sum
    // and the ·V product) — the paper's streaming softmax·V nest.
    let has_dot_child = steps.iter().any(|s| {
        matches!(s, FusedStep::Loop(c) if fused[*c].kernel == KernelId::DotAcc)
    });
    let n_accum = steps
        .iter()
        .filter(|s| matches!(s, FusedStep::Accum { .. }))
        .count();
    if has_dot_child && n_accum >= 2 {
        return KernelId::FlashInner;
    }
    KernelId::SerialNest
}

/// Coverage: a nest counts as fused when it executes entirely through
/// kernel bodies — collapsed outright, or (bottom-up) every body
/// instruction is `Fused` or a child loop that itself counts.
fn spec_report(loops: &[SymLoop], instrs: &[Instr], fused: &[FusedSite]) -> SpecReport {
    let mut counts = vec![false; loops.len()];
    for site in fused {
        if let Some(li) = site.loop_id {
            counts[li] = true;
        }
    }
    // Inner loops have higher indices than their parents, so reverse
    // order has every child verdict ready.
    for li in (0..loops.len()).rev() {
        if counts[li] || loops[li].end_ip == usize::MAX {
            continue;
        }
        let mut all_fused = true;
        let mut ip = loops[li].body_ip;
        while ip < loops[li].end_ip {
            match &instrs[ip] {
                Instr::Fused(_) => ip += 1,
                Instr::LoopBegin(lj) => {
                    if !counts[*lj] {
                        all_fused = false;
                        break;
                    }
                    ip = loops[*lj].end_ip + 1;
                }
                _ => {
                    all_fused = false;
                    break;
                }
            }
        }
        counts[li] = all_fused;
    }
    let mut by_kernel = std::collections::BTreeMap::new();
    for site in fused {
        *by_kernel.entry(site.kernel.name()).or_insert(0) += 1;
    }
    SpecReport {
        total_nests: loops.len(),
        fused_nests: counts.iter().filter(|c| **c).count(),
        by_kernel,
    }
}

// ---------------------------------------------------------------------------
// Parallel-safety analysis for grid loops
// ---------------------------------------------------------------------------

/// A `forall dim` loop can run its iterations concurrently iff sequential
/// execution could not observe any cross-iteration state:
///
/// * no direct-child accumulator (those carry across iterations; every
///   other var assigned in the body is in the loop's clear set, so each
///   iteration starts from scratch);
/// * vars read before assignment in the body (free vars) are **not also
///   assigned** in the body — genuinely loop-invariant. The engine seeds
///   each worker with the enclosing scope's var file, so reading outer
///   locals is safe; a var both free and assigned would be a
///   read-before-clear even sequentially;
/// * every store site indexes its buffer by `dim` (iterations write
///   disjoint slots) and no buffer is both read and written inside the
///   body (no iteration can observe another's stores);
/// * no inner loop shadows `dim` (which would defeat the previous check).
///
/// The analysis is structural — trip counts and extents play no role —
/// so it runs once per [`TapeSkeleton`] and survives re-binding. It
/// applies to nested loops exactly as to top-level ones: a serial outer
/// loop with a safe inner `forall` gets the inner loop annotated, which
/// the engine fans out per outer iteration.
fn loop_is_parallel(dim: &Dim, body: &[Stmt]) -> bool {
    if body.iter().any(|s| matches!(s, Stmt::Accum { .. })) {
        return false;
    }
    let mut assigned = HashSet::new();
    let mut free = HashSet::new();
    scan_reads(body, &mut assigned, &mut free);
    if free.iter().any(|v| assigned.contains(v)) {
        return false;
    }
    let mut loaded = HashSet::new();
    let mut stored = HashSet::new();
    if !stores_partitioned(body, dim, &mut loaded, &mut stored) {
        return false;
    }
    loaded.is_disjoint(&stored)
}

/// Sequential scan collecting vars read before any assignment (`free`).
fn scan_reads(stmts: &[Stmt], assigned: &mut HashSet<VarId>, free: &mut HashSet<VarId>) {
    for s in stmts {
        match s {
            Stmt::Load { var, .. } => {
                assigned.insert(*var);
            }
            Stmt::Store { var, .. } => {
                if !assigned.contains(var) {
                    free.insert(*var);
                }
            }
            Stmt::Compute { var, args, .. } => {
                for a in args {
                    if !assigned.contains(a) {
                        free.insert(*a);
                    }
                }
                assigned.insert(*var);
            }
            Stmt::Accum { var, src, .. } => {
                if !assigned.contains(src) {
                    free.insert(*src);
                }
                // reading `var` itself is fine: unassigned means
                // neutral-element initialization
                assigned.insert(*var);
            }
            Stmt::Loop { body, .. } => scan_reads(body, assigned, free),
            Stmt::MiscCall { .. } => {}
        }
    }
}

/// The grid dimension along which independent copies ("slices") of this
/// program can be stacked into one launch — the serving layer's
/// cross-request kernel coalescing — or `None` if no dimension
/// qualifies.
///
/// Executing the program with `dim -> B·d` must decompose into `B`
/// independent executions at `dim -> d`, slice `r` owning iterations
/// `[r·d, (r+1)·d)` of every top-level loop and the matching slab of
/// every `dim`-carrying buffer. That holds iff:
///
/// * every top-level statement is a `forall dim` grid loop over one and
///   the same `dim`, with no Rule-7 peel (`skip_first` would drop
///   iteration 0 of the *stacked* range only, not of every slice);
/// * each top loop passes the parallel-safety analysis behind
///   [`LoopMeta::parallel`] (`loop_is_parallel`), so iterations carry no
///   cross-iteration state and stores partition by `dim`;
/// * no top-level body reads a var it did not itself assign — a free
///   var would be seeded with an earlier nest's *final stacked*
///   iteration value (the last slice's data, not each slice's own);
/// * every buffer carries `dim` on at most one axis, and every access
///   (load/store index, misc-call slot) on that axis is `Iter(dim)` —
///   never `Zero` (slot 0 belongs to slice 0) and never ranging over
///   the whole axis. Buffers with no `dim` axis are shared by every
///   slice; partitioned stores already make them read-only, and the
///   caller must ensure all slices agree on their contents (the serving
///   layer verifies shared weight operands bitwise before coalescing).
///
/// Like the parallel-safety analysis, this is structural: trip counts
/// play no role, so the verdict survives re-binding to any `DimSizes`.
pub fn stackable_grid_dim(ir: &LoopIr) -> Option<Dim> {
    let mut dim: Option<Dim> = None;
    for s in &ir.body {
        let Stmt::Loop {
            kind: LoopKind::ForAll,
            dim: d,
            skip_first: false,
            body,
            ..
        } = s
        else {
            return None;
        };
        match &dim {
            None => dim = Some(d.clone()),
            Some(d0) if d0 == d => {}
            Some(_) => return None,
        }
        if !loop_is_parallel(d, body) {
            return None;
        }
        let mut assigned = HashSet::new();
        let mut free = HashSet::new();
        scan_reads(body, &mut assigned, &mut free);
        if !free.is_empty() {
            return None;
        }
    }
    let dim = dim?;
    for b in &ir.bufs {
        if b.dims.iter().filter(|d| **d == dim).count() > 1 {
            return None;
        }
    }
    accesses_slice_aligned(&ir.body, &ir.bufs, &dim).then_some(dim)
}

/// Shape-bucket legality: two `DimSizes` bindings of one program may
/// share a stacked launch iff they agree on every dimension except
/// (possibly) the stackable grid dim `dim` from [`stackable_grid_dim`].
/// Any *non*-grid dimension differing changes the shape of shared
/// (weight-like) operands and of each slice's inner loops, so those
/// requests can never ride one tape — the serving layer's shape buckets
/// reject them and fall back to exact-shape queues.
pub fn bucket_compatible(dim: &Dim, a: &DimSizes, b: &DimSizes) -> bool {
    a.0.len() == b.0.len()
        && a.0.keys().all(|d| b.0.contains_key(d))
        && a.0.iter().all(|(d, &n)| d == dim || b.0.get(d) == Some(&n))
}

/// Every access to a `dim`-carrying buffer axis must be `Iter(dim)`
/// (see [`stackable_grid_dim`]).
fn accesses_slice_aligned(stmts: &[Stmt], bufs: &[super::BufDecl], dim: &Dim) -> bool {
    let idx_ok = |buf: BufId, idx: &[Index]| -> bool {
        idx.iter().enumerate().all(|(i, ix)| {
            bufs[buf].dims[i] != *dim || matches!(ix, Index::Iter(d) if d == dim)
        })
    };
    let slots_ok = |buf: BufId, sels: &[Option<Index>]| -> bool {
        sels.iter().enumerate().all(|(i, sel)| {
            bufs[buf].dims[i] != *dim || matches!(sel, Some(Index::Iter(d)) if d == dim)
        })
    };
    for s in stmts {
        match s {
            Stmt::Load { buf, idx, .. } | Stmt::Store { buf, idx, .. } => {
                if !idx_ok(*buf, idx) {
                    return false;
                }
            }
            Stmt::MiscCall { args, out, .. } => {
                if args.iter().any(|(b, sels)| !slots_ok(*b, sels)) || !slots_ok(out.0, &out.1) {
                    return false;
                }
            }
            Stmt::Loop { body, .. } => {
                if !accesses_slice_aligned(body, bufs, dim) {
                    return false;
                }
            }
            Stmt::Compute { .. } | Stmt::Accum { .. } => {}
        }
    }
    true
}

/// Check every store is partitioned by `dim`; collect read/written bufs.
fn stores_partitioned(
    stmts: &[Stmt],
    dim: &Dim,
    loaded: &mut HashSet<BufId>,
    stored: &mut HashSet<BufId>,
) -> bool {
    for s in stmts {
        match s {
            Stmt::Load { buf, .. } => {
                loaded.insert(*buf);
            }
            Stmt::Store { buf, idx, .. } => {
                stored.insert(*buf);
                if !idx
                    .iter()
                    .any(|i| matches!(i, Index::Iter(d) if d == dim))
                {
                    return false;
                }
            }
            Stmt::MiscCall { args, out, .. } => {
                for (b, _) in args {
                    loaded.insert(*b);
                }
                stored.insert(out.0);
                if !out
                    .1
                    .iter()
                    .any(|i| matches!(i, Some(Index::Iter(d)) if d == dim))
                {
                    return false;
                }
            }
            Stmt::Loop { dim: d2, body, .. } => {
                if d2 == dim {
                    return false;
                }
                if !stores_partitioned(body, dim, loaded, stored) {
                    return false;
                }
            }
            Stmt::Accum { .. } | Stmt::Compute { .. } => {}
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::dim::DimSizes;
    use crate::ir::types::Item;
    use crate::loopir::BufDecl;

    fn grid_ir(kind: LoopKind) -> LoopIr {
        // forall/for m { t0 = load A[m]; t1 = t0+t0; store t1 -> B[m] }
        let m = Dim::new("M");
        let mut ir = LoopIr {
            bufs: vec![
                BufDecl {
                    name: "A".into(),
                    dims: vec![m.clone()],
                    item: Item::Block,
                    is_input: true,
                    is_output: false,
                    state_dim: None,
                },
                BufDecl {
                    name: "B".into(),
                    dims: vec![m.clone()],
                    item: Item::Block,
                    is_input: false,
                    is_output: true,
                    state_dim: None,
                },
            ],
            body: vec![Stmt::Loop {
                kind,
                dim: m.clone(),
                skip_first: false,
                clears: vec![],
                body: vec![
                    Stmt::Load {
                        var: 0,
                        buf: 0,
                        idx: vec![Index::Iter(m.clone())],
                    },
                    Stmt::Compute {
                        var: 1,
                        op: COp::Func(FuncOp::Add),
                        args: vec![0, 0],
                    },
                    Stmt::Store {
                        var: 1,
                        buf: 1,
                        idx: vec![Index::Iter(m)],
                    },
                ],
            }],
            n_vars: 2,
            params: vec![],
        };
        super::super::analyze_clears(&mut ir);
        ir
    }

    #[test]
    fn tape_shape_and_parallel_flag() {
        let ir = grid_ir(LoopKind::ForAll);
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 3)]));
        let p = compile(&ir, &cfg);
        assert_eq!(p.loops.len(), 1);
        assert_eq!(p.loops[0].trip, 3);
        assert_eq!(p.n_regs, 1);
        assert_eq!(p.tops.len(), 1);
        assert!(p.tops[0].kernel);
        assert!(p.loops[0].parallel, "grid loop must be parallel");
        // LoopBegin, Load, Compute, Store, LoopEnd
        assert_eq!(p.instrs.len(), 5);
        assert_eq!(p.parallel_grid_loops(), 1);
    }

    #[test]
    fn serial_loop_not_parallel() {
        let ir = grid_ir(LoopKind::For);
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 3)]));
        let p = compile(&ir, &cfg);
        assert!(!p.loops[0].parallel);
    }

    #[test]
    fn store_without_grid_index_rejected() {
        // forall m { t0 = load A[m]; store t0 -> B[0] } — all iterations
        // write the same slot: must stay sequential.
        let mut ir = grid_ir(LoopKind::ForAll);
        if let Stmt::Loop { body, .. } = &mut ir.body[0] {
            body[2] = Stmt::Store {
                var: 1,
                buf: 1,
                idx: vec![Index::Zero],
            };
        }
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 3)]));
        let p = compile(&ir, &cfg);
        assert!(!p.loops[0].parallel);
    }

    #[test]
    fn loop_invariant_free_var_read_allowed() {
        // forall m { t1 = t9 + t9; store t1 -> B[m] } — t9 comes from
        // outside the loop and is never assigned inside it: the engine
        // seeds workers with the enclosing var file, so this is safe.
        let mut ir = grid_ir(LoopKind::ForAll);
        if let Stmt::Loop { body, .. } = &mut ir.body[0] {
            body[1] = Stmt::Compute {
                var: 1,
                op: COp::Func(FuncOp::Add),
                args: vec![9, 9],
            };
        }
        ir.n_vars = 10;
        super::super::analyze_clears(&mut ir);
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 3)]));
        let p = compile(&ir, &cfg);
        assert!(p.loops[0].parallel);
    }

    #[test]
    fn free_var_also_assigned_rejected() {
        // forall m { t1 = t1 + t1; store t1 -> B[m] } — t1 is read before
        // it is assigned *and* assigned in the body: cross-iteration (and
        // sequentially a read-before-clear), so it must stay serial.
        let mut ir = grid_ir(LoopKind::ForAll);
        if let Stmt::Loop { body, .. } = &mut ir.body[0] {
            body.remove(0); // drop the load; body: t1 = t1+t1; store t1
            body[0] = Stmt::Compute {
                var: 1,
                op: COp::Func(FuncOp::Add),
                args: vec![1, 1],
            };
        }
        super::super::analyze_clears(&mut ir);
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 3)]));
        let p = compile(&ir, &cfg);
        assert!(!p.loops[0].parallel);
    }

    #[test]
    fn nested_forall_under_serial_loop_annotated() {
        // for m { forall n { t0 = load A[m,n]; t1 = t0+t0;
        //                    store t1 -> B[m,n] } }
        // The serial outer loop is not parallel; the inner grid is.
        let (m, n) = (Dim::new("M"), Dim::new("N"));
        let buf = |name: &str, is_input: bool| BufDecl {
            name: name.into(),
            dims: vec![m.clone(), n.clone()],
            item: Item::Block,
            is_input,
            is_output: !is_input,
            state_dim: None,
        };
        let mut ir = LoopIr {
            bufs: vec![buf("A", true), buf("B", false)],
            body: vec![Stmt::Loop {
                kind: LoopKind::For,
                dim: m.clone(),
                skip_first: false,
                clears: vec![],
                body: vec![Stmt::Loop {
                    kind: LoopKind::ForAll,
                    dim: n.clone(),
                    skip_first: false,
                    clears: vec![],
                    body: vec![
                        Stmt::Load {
                            var: 0,
                            buf: 0,
                            idx: vec![Index::Iter(m.clone()), Index::Iter(n.clone())],
                        },
                        Stmt::Compute {
                            var: 1,
                            op: COp::Func(FuncOp::Add),
                            args: vec![0, 0],
                        },
                        Stmt::Store {
                            var: 1,
                            buf: 1,
                            idx: vec![Index::Iter(m), Index::Iter(n)],
                        },
                    ],
                }],
            }],
            n_vars: 2,
            params: vec![],
        };
        super::super::analyze_clears(&mut ir);
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 2), ("N", 8)]));
        let p = compile(&ir, &cfg);
        assert_eq!(p.loops.len(), 2);
        assert!(!p.loops[0].parallel, "serial outer loop");
        assert!(p.loops[1].parallel, "inner grid loop");
        assert_eq!(p.parallel_grid_loops(), 1);
        // inner: 8 iterations × 3 instrs; outer folds the inner in
        assert_eq!(p.loops[1].weight, 24);
        assert_eq!(p.loops[0].weight, 48);
    }

    #[test]
    fn access_strides_row_major() {
        // B[m, n] with M=3, N=4: stride of m is 4, of n is 1.
        let (m, n) = (Dim::new("M"), Dim::new("N"));
        let mut ir = LoopIr {
            bufs: vec![BufDecl {
                name: "B".into(),
                dims: vec![m.clone(), n.clone()],
                item: Item::Block,
                is_input: false,
                is_output: true,
                state_dim: None,
            }],
            body: vec![Stmt::Loop {
                kind: LoopKind::ForAll,
                dim: m.clone(),
                skip_first: false,
                clears: vec![],
                body: vec![Stmt::Loop {
                    kind: LoopKind::ForAll,
                    dim: n.clone(),
                    skip_first: false,
                    clears: vec![],
                    body: vec![Stmt::Store {
                        var: 0,
                        buf: 0,
                        idx: vec![Index::Iter(m), Index::Iter(n)],
                    }],
                }],
            }],
            n_vars: 1,
            params: vec![],
        };
        super::super::analyze_clears(&mut ir);
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 3), ("N", 4)]));
        let p = compile(&ir, &cfg);
        assert_eq!(p.accesses.len(), 1);
        assert_eq!(p.accesses[0].terms, vec![(0, 4), (1, 1)]);
        assert_eq!(p.accesses[0].flat(&[2, 3]), 11);
    }

    /// Regression: elementwise arity above 8 used to hit
    /// `assert!(n <= 8, "elementwise arity > 8 unsupported")`; the
    /// marshalling now falls back to heap-allocated argument buffers and
    /// must agree with per-element evaluation on scalars, vectors, and
    /// blocks.
    #[test]
    fn elementwise_arity_nine_supported() {
        use crate::ir::expr::Expr;
        use crate::ir::exprvm::{EwKernel, EwScratch};
        // x0 + x1 + ... + x8 (arity 9)
        let mut e = Expr::var(0);
        for i in 1..9 {
            e = e.add(Expr::var(i));
        }
        let ce = e.compile(&std::collections::BTreeMap::new());
        assert_eq!(ce.arity, 9);
        let kind = ComputeKind::Ew(EwKernel::new(ce.clone()));
        let mut scratch = EwScratch::new();

        let scalars: Vec<Val> = (0..9).map(|i| Val::Scalar(i as f32 * 0.5 - 2.0)).collect();
        let refs: Vec<&Val> = scalars.iter().collect();
        let (v, fl) = kind.apply(&refs, &mut scratch);
        let xs: Vec<f32> = scalars.iter().map(|s| s.as_scalar()).collect();
        assert_eq!(v, Val::Scalar(ce.eval_with(&xs, &mut scratch.stack)));
        assert_eq!(fl, 1);

        let blocks: Vec<Val> = (0..9)
            .map(|i| Val::Block(Mat::from_fn(3, 5, |r, c| (r * 5 + c) as f32 * 0.1 + i as f32)))
            .collect();
        let refs: Vec<&Val> = blocks.iter().collect();
        let (v, fl) = kind.apply(&refs, &mut scratch);
        let got = v.as_block();
        for idx in 0..15 {
            let xs: Vec<f32> = blocks.iter().map(|b| b.as_block().data[idx]).collect();
            let want = ce.eval_with(&xs, &mut scratch.stack);
            assert_eq!(got.data[idx].to_bits(), want.to_bits(), "element {idx}");
        }
        assert_eq!(fl, 15);
    }

    /// Stackability: the plain grid map stacks along its grid dim; every
    /// structural hazard (serial loop, unpartitioned store, Rule-7 peel,
    /// cross-slice `Zero` access, free-var seeding, mixed top dims)
    /// disqualifies.
    #[test]
    fn stackable_grid_dim_accepts_plain_grid() {
        let ir = grid_ir(LoopKind::ForAll);
        assert_eq!(stackable_grid_dim(&ir), Some(Dim::new("M")));
    }

    #[test]
    fn stackable_grid_dim_rejects_hazards() {
        // serial top loop
        assert_eq!(stackable_grid_dim(&grid_ir(LoopKind::For)), None);

        // Rule-7 peel on the grid loop
        let mut ir = grid_ir(LoopKind::ForAll);
        if let Stmt::Loop { skip_first, .. } = &mut ir.body[0] {
            *skip_first = true;
        }
        assert_eq!(stackable_grid_dim(&ir), None);

        // store not partitioned by the grid dim (Zero on the M axis)
        let mut ir = grid_ir(LoopKind::ForAll);
        if let Stmt::Loop { body, .. } = &mut ir.body[0] {
            body[2] = Stmt::Store {
                var: 1,
                buf: 1,
                idx: vec![Index::Zero],
            };
        }
        assert_eq!(stackable_grid_dim(&ir), None);

        // a load from slot 0 of the grid axis reads slice 0's data
        let mut ir = grid_ir(LoopKind::ForAll);
        if let Stmt::Loop { body, .. } = &mut ir.body[0] {
            body[0] = Stmt::Load {
                var: 0,
                buf: 0,
                idx: vec![Index::Zero],
            };
        }
        assert_eq!(stackable_grid_dim(&ir), None);

        // free-var read (parallel-safe via seeding, but seeded with the
        // final stacked iteration's value — cross-slice)
        let mut ir = grid_ir(LoopKind::ForAll);
        if let Stmt::Loop { body, .. } = &mut ir.body[0] {
            body[1] = Stmt::Compute {
                var: 1,
                op: COp::Func(FuncOp::Add),
                args: vec![9, 9],
            };
        }
        ir.n_vars = 10;
        super::super::analyze_clears(&mut ir);
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 3)]));
        assert!(compile(&ir, &cfg).loops[0].parallel, "parallel but not stackable");
        assert_eq!(stackable_grid_dim(&ir), None);

        // two top-level grids over different dims
        let mut ir = grid_ir(LoopKind::ForAll);
        let second = ir.body[0].clone();
        ir.body.push(second);
        if let Stmt::Loop { dim, body, .. } = &mut ir.body[1] {
            *dim = Dim::new("N");
            // rewrite body accesses to stay rank-consistent is unneeded:
            // the dim mismatch alone must reject
            let _ = body;
        }
        assert_eq!(stackable_grid_dim(&ir), None);
    }

    /// Shape-bucket legality: bindings differing only in the stackable
    /// grid dim are compatible; any non-grid difference — value, missing
    /// dim, or extra dim — rejects.
    #[test]
    fn bucket_compatibility_is_grid_dim_only() {
        let m = Dim::new("M");
        let a = DimSizes::of(&[("M", 4), ("K", 2), ("N", 3)]);
        let b = DimSizes::of(&[("M", 1), ("K", 2), ("N", 3)]);
        assert!(bucket_compatible(&m, &a, &b), "M-only difference buckets");
        assert!(bucket_compatible(&m, &a, &a), "identical shapes bucket");
        assert!(bucket_compatible(&m, &b, &a), "symmetric");

        let k_differs = DimSizes::of(&[("M", 4), ("K", 5), ("N", 3)]);
        assert!(!bucket_compatible(&m, &a, &k_differs), "non-grid dim differs");
        let missing = DimSizes::of(&[("M", 4), ("K", 2)]);
        assert!(!bucket_compatible(&m, &a, &missing), "missing dim");
        assert!(!bucket_compatible(&m, &missing, &a), "extra dim");
        let renamed = DimSizes::of(&[("M", 4), ("K", 2), ("P", 3)]);
        assert!(!bucket_compatible(&m, &a, &renamed), "same count, different dims");
    }

    /// The skeleton/bind split: one skeleton re-bound to two size
    /// assignments yields the same tapes `compile` would build, with
    /// annotations intact and only the size tables differing.
    #[test]
    fn skeleton_rebinds_across_sizes() {
        let ir = grid_ir(LoopKind::ForAll);
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 3)]));
        let skel = compile_skeleton(&ir, &cfg);
        let p3 = skel.bind(&DimSizes::of(&[("M", 3)]));
        let p6 = skel.bind(&DimSizes::of(&[("M", 6)]));
        assert_eq!(p3.loops[0].trip, 3);
        assert_eq!(p6.loops[0].trip, 6);
        // weight = iterations × body instructions (Load, Compute, Store)
        assert_eq!(p3.loops[0].weight, 9);
        assert_eq!(p6.loops[0].weight, 18);
        assert_eq!(p3.instrs.len(), p6.instrs.len());
        assert!(p3.loops[0].parallel && p6.loops[0].parallel);
        assert_eq!(p3.bufs[0].dims, vec![3]);
        assert_eq!(p6.bufs[0].dims, vec![6]);
        // direct compile at M=6 produces the same shape
        let direct = compile(&ir, &ExecConfig::new(DimSizes::of(&[("M", 6)])));
        assert_eq!(direct.loops[0].trip, p6.loops[0].trip);
        assert_eq!(direct.accesses[0].terms, p6.accesses[0].terms);
    }

    /// `forall m { for k { a = A[m,k]; b = B[k]; t = dot(a,b); acc += t };
    ///             store acc -> C[m] }` — the canonical contraction.
    fn contraction_ir() -> LoopIr {
        let (m, k) = (Dim::new("M"), Dim::new("K"));
        let mut ir = LoopIr {
            bufs: vec![
                BufDecl {
                    name: "A".into(),
                    dims: vec![m.clone(), k.clone()],
                    item: Item::Block,
                    is_input: true,
                    is_output: false,
                    state_dim: None,
                },
                BufDecl {
                    name: "B".into(),
                    dims: vec![k.clone()],
                    item: Item::Block,
                    is_input: true,
                    is_output: false,
                    state_dim: None,
                },
                BufDecl {
                    name: "C".into(),
                    dims: vec![m.clone()],
                    item: Item::Block,
                    is_input: false,
                    is_output: true,
                    state_dim: None,
                },
            ],
            body: vec![Stmt::Loop {
                kind: LoopKind::ForAll,
                dim: m.clone(),
                skip_first: false,
                clears: vec![],
                body: vec![
                    Stmt::Loop {
                        kind: LoopKind::For,
                        dim: k.clone(),
                        skip_first: false,
                        clears: vec![],
                        body: vec![
                            Stmt::Load {
                                var: 0,
                                buf: 0,
                                idx: vec![Index::Iter(m.clone()), Index::Iter(k.clone())],
                            },
                            Stmt::Load {
                                var: 1,
                                buf: 1,
                                idx: vec![Index::Iter(k)],
                            },
                            Stmt::Compute {
                                var: 2,
                                op: COp::Func(FuncOp::Dot),
                                args: vec![0, 1],
                            },
                            Stmt::Accum {
                                var: 3,
                                op: ReduceOp::Add,
                                src: 2,
                            },
                        ],
                    },
                    Stmt::Store {
                        var: 3,
                        buf: 2,
                        idx: vec![Index::Iter(m)],
                    },
                ],
            }],
            n_vars: 4,
            params: vec![],
        };
        super::super::analyze_clears(&mut ir);
        ir
    }

    /// The specialization pass collapses the serial contraction loop
    /// into a `dot_acc` site, wraps the remaining straight-line body
    /// into a run site, and reports full coverage — while bind-time
    /// loop weights stay identical to the unspecialized tape, so
    /// nested fan-out decisions cannot diverge.
    #[test]
    fn specialize_collapses_dot_contraction() {
        let ir = contraction_ir();
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 3), ("K", 4)]));
        let skel = compile_skeleton(&ir, &cfg);
        let spec = specialize_skeleton(&skel);

        // the k loop (index 1, inner) collapsed to a DotAcc loop site;
        // the m body (Fused + Store) wrapped into one StreamRun
        let rep = spec.spec.as_ref().expect("specialized skeleton has a report");
        assert_eq!(rep.total_nests, 2);
        assert_eq!(rep.fused_nests, 2, "both nests run through kernel bodies");
        assert_eq!(rep.by_kernel.get("dot_acc"), Some(&1));
        assert_eq!(rep.by_kernel.get("stream_run"), Some(&1));
        let dot = spec
            .fused
            .iter()
            .find(|s| s.kernel == KernelId::DotAcc)
            .expect("dot site");
        assert_eq!(dot.loop_id, Some(1));
        assert_eq!(dot.steps.len(), 4);
        // top-level m loop keeps its literal LoopBegin/LoopEnd
        assert!(matches!(spec.instrs[0], Instr::LoopBegin(0)));
        assert!(matches!(spec.instrs[1], Instr::Fused(_)));
        assert!(matches!(spec.instrs[2], Instr::LoopEnd(0)));
        assert_eq!(spec.instrs.len(), 3);
        // collapsed k loop is poisoned; surviving m loop re-pointed
        assert_eq!(spec.loops[1].body_ip, usize::MAX);
        assert_eq!(spec.loops[0].body_ip, 1);
        assert_eq!(spec.loops[0].end_ip, 2);

        // weight parity: fused regions charge exactly what the original
        // instructions would have
        let plain = skel.bind(&cfg.sizes);
        let fused = spec.bind(&cfg.sizes);
        assert_eq!(plain.loops[1].weight, 16, "K=4 × 4 body instrs");
        assert_eq!(plain.loops[0].weight, 51, "M=3 × (16 + store)");
        assert_eq!(fused.loops[0].weight, plain.loops[0].weight);
        assert_eq!(fused.loops[1].weight, plain.loops[1].weight);
        assert_eq!(fused.loops[0].parallel, plain.loops[0].parallel);
    }

    /// Parallel grid loops are never collapsed (fan-out and slice
    /// attribution hang off their `LoopBegin`), but their straight-line
    /// bodies become one run site — so even map-only programs report
    /// coverage.
    #[test]
    fn specialize_wraps_runs_inside_parallel_grid() {
        let ir = grid_ir(LoopKind::ForAll);
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 3)]));
        let spec = specialize_skeleton(&compile_skeleton(&ir, &cfg));
        assert!(matches!(spec.instrs[0], Instr::LoopBegin(0)));
        assert!(matches!(spec.instrs[1], Instr::Fused(0)));
        assert!(matches!(spec.instrs[2], Instr::LoopEnd(0)));
        assert!(spec.loops[0].parallel, "grid loop survives untouched");
        assert_eq!(spec.fused[0].kernel, KernelId::StreamRun);
        assert_eq!(spec.fused[0].loop_id, None);
        assert_eq!(spec.fused[0].steps.len(), 3);
        let rep = spec.spec.as_ref().unwrap();
        assert_eq!((rep.fused_nests, rep.total_nests), (1, 1));
        // run-site weight = its step count, same as the plain body
        let plain = compile_skeleton(&ir, &cfg).bind(&cfg.sizes);
        assert_eq!(spec.bind(&cfg.sizes).loops[0].weight, plain.loops[0].weight);
    }

    /// Specializing twice is an identity — prepared-plan paths may hand
    /// an already-specialized skeleton back through the pass.
    #[test]
    fn specialize_is_idempotent() {
        let ir = contraction_ir();
        let cfg = ExecConfig::new(DimSizes::of(&[("M", 2), ("K", 2)]));
        let once = specialize_skeleton(&compile_skeleton(&ir, &cfg));
        let twice = specialize_skeleton(&once);
        assert_eq!(format!("{:?}", once.instrs), format!("{:?}", twice.instrs));
        assert_eq!(format!("{:?}", once.fused), format!("{:?}", twice.fused));
        assert_eq!(
            once.spec.as_ref().unwrap().fused_nests,
            twice.spec.as_ref().unwrap().fused_nests
        );
    }
}
