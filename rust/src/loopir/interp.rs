//! Loop IR interpreter with a two-tier-memory simulator.
//!
//! Executes a lowered block program on concrete data, modeling the paper's
//! abstract machine: buffers live in *global memory*; vars live in *local
//! memory*; every `load`/`store` is a global<->local block transfer and is
//! charged to [`MemSim`]. The interpreter is the ground truth used to verify
//! that every substitution rule is logic-preserving, and `MemSim`'s counters
//! are the quantity fusion optimizes (global-memory traffic + kernel
//! launches).

use super::compile::{accum_val, ComputeKind};
use super::{Index, LoopIr, Stmt};
use crate::ir::dim::{Dim, DimSizes};
use crate::tensor::Val;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// Two-tier memory traffic counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MemSim {
    /// Bytes copied global -> local.
    pub loaded_bytes: u64,
    /// Bytes copied local -> global.
    pub stored_bytes: u64,
    pub n_loads: u64,
    pub n_stores: u64,
    /// Peak bytes of live local values (approximation: sum of live vars in
    /// the executing scope chain).
    pub peak_local_bytes: u64,
    /// Top-level loop nests executed (kernel launches).
    pub kernel_launches: u64,
    /// Scalar fused multiply-add count of block operations (compute work,
    /// used to quantify Rule-6 work replication).
    pub flops: u64,
    /// Of `loaded_bytes`, the share attributable to pad rows in a
    /// padded stacked launch (see the serving layer's pad-to-bucket
    /// path). Always `0` for ordinary executions and for per-request
    /// counters: pad waste is charged to the *aggregate* only, so
    /// `loaded_bytes == Σ per-request loaded_bytes + padded_loaded_bytes`
    /// reconciles exactly.
    pub padded_loaded_bytes: u64,
    /// Pad share of `stored_bytes` (same contract as
    /// `padded_loaded_bytes`).
    pub padded_stored_bytes: u64,
    /// Pad share of `flops` (same contract as `padded_loaded_bytes`).
    pub padded_flops: u64,
    /// Of `stored_bytes`, the share spent appending new blocks to a
    /// *stateful* buffer (a KV cache growing across decode steps; see
    /// `exec::append_state`). A decode step's traffic is its stateless
    /// equivalent plus exactly this breakout:
    /// `stored_bytes == stateless.stored_bytes + state_appended_bytes`.
    pub state_appended_bytes: u64,
    /// Block-granular append count paired with `state_appended_bytes`
    /// (same contract: `n_stores == stateless.n_stores + state_appends`).
    pub state_appends: u64,
}

impl MemSim {
    pub fn total_traffic(&self) -> u64 {
        self.loaded_bytes + self.stored_bytes
    }

    /// Fold `o` into `self`: additive counters sum, `peak_local_bytes`
    /// merges by max (it is a peak, not a flow). This is the one merge
    /// rule every layer uses — the engine's worker join, the
    /// coordinator's per-segment totals, and per-slice attribution.
    pub fn add_counters(&mut self, o: &MemSim) {
        self.loaded_bytes += o.loaded_bytes;
        self.stored_bytes += o.stored_bytes;
        self.n_loads += o.n_loads;
        self.n_stores += o.n_stores;
        self.kernel_launches += o.kernel_launches;
        self.flops += o.flops;
        self.padded_loaded_bytes += o.padded_loaded_bytes;
        self.padded_stored_bytes += o.padded_stored_bytes;
        self.padded_flops += o.padded_flops;
        self.state_appended_bytes += o.state_appended_bytes;
        self.state_appends += o.state_appends;
        self.peak_local_bytes = self.peak_local_bytes.max(o.peak_local_bytes);
    }

    /// Counters accrued since `base` (a prior snapshot of `self`).
    /// `peak_local_bytes` is not additive, so the delta carries the
    /// current absolute peak — callers treat it as the estimate it is.
    pub fn counter_delta(&self, base: &MemSim) -> MemSim {
        MemSim {
            loaded_bytes: self.loaded_bytes - base.loaded_bytes,
            stored_bytes: self.stored_bytes - base.stored_bytes,
            n_loads: self.n_loads - base.n_loads,
            n_stores: self.n_stores - base.n_stores,
            peak_local_bytes: self.peak_local_bytes,
            kernel_launches: self.kernel_launches - base.kernel_launches,
            flops: self.flops - base.flops,
            padded_loaded_bytes: self.padded_loaded_bytes - base.padded_loaded_bytes,
            padded_stored_bytes: self.padded_stored_bytes - base.padded_stored_bytes,
            padded_flops: self.padded_flops - base.padded_flops,
            state_appended_bytes: self.state_appended_bytes - base.state_appended_bytes,
            state_appends: self.state_appends - base.state_appends,
        }
    }
}

/// A multi-dimensional global buffer of local items.
#[derive(Clone, Debug)]
pub struct BufVal {
    pub dims: Vec<usize>,
    /// Elements are reference-counted (`Arc`, so the compiled engine can
    /// share them across worker threads) and the simulator's loads/stores
    /// move pointers, not payloads (§Perf round 2); *simulated* traffic is
    /// still charged in full by `MemSim`.
    pub data: Vec<Option<Arc<Val>>>,
}

impl BufVal {
    pub fn new(dims: Vec<usize>) -> BufVal {
        let n: usize = dims.iter().product::<usize>().max(1);
        BufVal {
            dims,
            data: vec![None; n],
        }
    }

    pub fn scalar_item(v: Val) -> BufVal {
        BufVal {
            dims: vec![],
            data: vec![Some(Arc::new(v))],
        }
    }

    fn flat(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.dims.len(), "BufVal index rank mismatch");
        let mut f = 0;
        for (i, &x) in idx.iter().enumerate() {
            assert!(x < self.dims[i], "BufVal index {x} out of dim {}", self.dims[i]);
            f = f * self.dims[i] + x;
        }
        f
    }

    pub fn get(&self, idx: &[usize]) -> &Val {
        self.data[self.flat(idx)]
            .as_deref()
            .unwrap_or_else(|| panic!("BufVal: element {idx:?} never stored"))
    }

    fn get_arc(&self, idx: &[usize]) -> Arc<Val> {
        self.data[self.flat(idx)]
            .clone()
            .unwrap_or_else(|| panic!("BufVal: element {idx:?} never stored"))
    }

    pub fn set(&mut self, idx: &[usize], v: Val) {
        let f = self.flat(idx);
        self.data[f] = Some(Arc::new(v));
    }

    fn set_arc(&mut self, idx: &[usize], v: Arc<Val>) {
        let f = self.flat(idx);
        self.data[f] = Some(v);
    }
}

/// Execution configuration: dim sizes, scalar parameters, input buffers,
/// optional local-memory capacity (bytes) to enforce, and misc-op callbacks.
#[derive(Clone)]
pub struct ExecConfig {
    pub sizes: DimSizes,
    pub params: BTreeMap<String, f32>,
    pub inputs: HashMap<String, BufVal>,
    /// If set, executing with live local state above this capacity panics —
    /// used by the autotuner tests to verify capacity feasibility.
    pub local_capacity: Option<u64>,
    pub misc_ops: HashMap<String, fn(&[Val]) -> Val>,
    /// Whole-array opaque operators: take the row-major element lists of
    /// each input buffer, return the output's elements in row-major order.
    pub misc_list_ops: HashMap<String, fn(&[Vec<Val>]) -> Vec<Val>>,
    /// Worker-thread cap for the compiled engine's parallel grid loops
    /// (`None` = one worker per available core). The tree-walking
    /// interpreter ignores this — it is always sequential.
    pub threads: Option<usize>,
    /// `Some(widths)`: split traffic attribution into `widths.len()`
    /// contiguous grid slices of every top-level loop — slice `r` covers
    /// `widths[r]` consecutive iterations — reported in
    /// [`ExecResult::per_slice`]. This is the serving layer's
    /// stacked-batch path: slice `r` of a coalesced launch is request
    /// `r`'s traffic, and ragged batches (different per-request trips,
    /// or interleaved pad slices) use unequal widths. Requires every
    /// top-level statement to be a grid loop whose trip count equals
    /// `widths.iter().sum()` (see `loopir::compile::stackable_grid_dim`).
    /// Each non-empty slice is also charged one kernel launch per
    /// top-level nest — what it would have paid running alone — while
    /// the aggregate counters keep the single stacked launch; zero-width
    /// slices charge nothing. `None`: no attribution (the normal path).
    pub slices: Option<Vec<usize>>,
}

impl ExecConfig {
    pub fn new(sizes: DimSizes) -> ExecConfig {
        ExecConfig {
            sizes,
            params: BTreeMap::new(),
            inputs: HashMap::new(),
            local_capacity: None,
            misc_ops: HashMap::new(),
            misc_list_ops: HashMap::new(),
            threads: None,
            slices: None,
        }
    }
}

/// Result of executing a program.
pub struct ExecResult {
    pub outputs: HashMap<String, BufVal>,
    pub mem: MemSim,
    /// Per-slice traffic attribution — one entry per slice when
    /// [`ExecConfig::slices`] is set, empty otherwise. Slice `r`'s
    /// counters are bit-identical to what a standalone execution of the
    /// slice's sub-problem would charge (`peak_local_bytes` excepted:
    /// it reports the executing machine's running peak).
    pub per_slice: Vec<MemSim>,
}

struct Interp<'a> {
    cfg: &'a ExecConfig,
    bufs: Vec<BufVal>,
    vars: Vec<Option<Arc<Val>>>,
    iters: HashMap<Dim, usize>,
    mem: MemSim,
    live_local: u64,
}

/// Execute `ir` under `cfg`.
pub fn exec(ir: &LoopIr, cfg: &ExecConfig) -> ExecResult {
    let mut bufs = Vec::with_capacity(ir.bufs.len());
    for decl in &ir.bufs {
        let dims: Vec<usize> = decl.dims.iter().map(|d| cfg.sizes.get(d)).collect();
        if decl.is_input {
            let bv = cfg
                .inputs
                .get(&decl.name)
                .unwrap_or_else(|| panic!("missing input buffer {}", decl.name))
                .clone();
            assert_eq!(
                bv.dims, dims,
                "input {} has dims {:?}, program expects {:?}",
                decl.name, bv.dims, dims
            );
            bufs.push(bv);
        } else {
            bufs.push(BufVal::new(dims));
        }
    }
    let mut it = Interp {
        cfg,
        bufs,
        vars: vec![None; ir.n_vars],
        iters: HashMap::new(),
        mem: MemSim::default(),
        live_local: 0,
    };
    let mut per_slice =
        vec![MemSim::default(); cfg.slices.as_ref().map(|w| w.len()).unwrap_or(0)];
    for s in &ir.body {
        if matches!(s, Stmt::Loop { .. }) {
            it.mem.kernel_launches += 1;
        }
        match (cfg.slices.as_deref(), s) {
            (None, _) => it.stmt(s),
            (
                Some(widths),
                Stmt::Loop {
                    dim,
                    skip_first,
                    body,
                    clears,
                    ..
                },
            ) => {
                // Slice-attributed drive: same per-iteration semantics
                // (clears, then body) as `Interp::stmt`, with counter
                // deltas recorded at slice boundaries. Each non-empty
                // slice also gets the kernel launch it would pay
                // running alone.
                assert!(
                    !*skip_first,
                    "slice attribution: top-level loop over {dim} must not skip iteration 0"
                );
                let n = cfg.sizes.get(dim);
                let total: usize = widths.iter().sum();
                assert!(
                    !widths.is_empty() && total == n,
                    "slice attribution: widths {widths:?} do not cover {n} iterations of {dim}"
                );
                let mut x0 = 0usize;
                for (&w, slice) in widths.iter().zip(per_slice.iter_mut()) {
                    let base = it.mem.clone();
                    for x in x0..x0 + w {
                        for &c in clears {
                            it.clear_var(c);
                        }
                        it.iters.insert(dim.clone(), x);
                        for st in body {
                            it.stmt(st);
                        }
                    }
                    x0 += w;
                    if w > 0 {
                        let mut delta = it.mem.counter_delta(&base);
                        delta.kernel_launches += 1;
                        slice.add_counters(&delta);
                    }
                }
                it.iters.remove(dim);
            }
            (Some(_), _) => {
                panic!("slice attribution requires every top-level statement to be a grid loop")
            }
        }
    }
    let mut outputs = HashMap::new();
    for (i, decl) in ir.bufs.iter().enumerate() {
        if decl.is_output {
            outputs.insert(decl.name.clone(), it.bufs[i].clone());
        }
    }
    ExecResult {
        outputs,
        mem: it.mem,
        per_slice,
    }
}

impl<'a> Interp<'a> {
    /// Resolve an index expression into the caller-provided fixed buffer
    /// (§Perf round 3: no per-load allocation).
    #[inline]
    fn idx_into<'b>(&self, idx: &[Index], out: &'b mut [usize; 8]) -> &'b [usize] {
        for (k, i) in idx.iter().enumerate() {
            out[k] = match i {
                Index::Iter(d) => *self
                    .iters
                    .get(d)
                    .unwrap_or_else(|| panic!("no enclosing loop over {d}")),
                Index::Zero => 0,
            };
        }
        &out[..idx.len()]
    }

    fn set_var(&mut self, var: usize, v: Arc<Val>) {
        if let Some(old) = &self.vars[var] {
            self.live_local = self.live_local.saturating_sub(old.bytes() as u64);
        }
        self.live_local += v.bytes() as u64;
        self.vars[var] = Some(v);
        if self.live_local > self.mem.peak_local_bytes {
            self.mem.peak_local_bytes = self.live_local;
        }
        if let Some(cap) = self.cfg.local_capacity {
            assert!(
                self.live_local <= cap,
                "local memory capacity exceeded: {} > {cap}",
                self.live_local
            );
        }
    }

    fn clear_var(&mut self, var: usize) {
        if let Some(old) = self.vars[var].take() {
            self.live_local = self.live_local.saturating_sub(old.bytes() as u64);
        }
    }

    fn var(&self, v: usize) -> &Val {
        self.vars[v]
            .as_deref()
            .unwrap_or_else(|| panic!("var t{v} read before assignment"))
    }

    fn var_arc(&self, v: usize) -> Arc<Val> {
        self.vars[v]
            .clone()
            .unwrap_or_else(|| panic!("var t{v} read before assignment"))
    }

    fn stmt(&mut self, s: &Stmt) {
        match s {
            Stmt::Loop {
                dim,
                skip_first,
                body,
                clears,
                ..
            } => {
                let n = self.cfg.sizes.get(dim);
                let start = if *skip_first { 1 } else { 0 };
                for x in start..n {
                    for &c in clears {
                        self.clear_var(c);
                    }
                    self.iters.insert(dim.clone(), x);
                    for st in body {
                        self.stmt(st);
                    }
                }
                self.iters.remove(dim);
            }
            Stmt::Load { var, buf, idx } => {
                let mut scratch = [0usize; 8];
                let i = self.idx_into(idx, &mut scratch);
                let v = self.bufs[*buf].get_arc(i);
                self.mem.n_loads += 1;
                self.mem.loaded_bytes += v.bytes() as u64;
                self.set_var(*var, v);
            }
            Stmt::Store { var, buf, idx } => {
                let mut scratch = [0usize; 8];
                let i = self.idx_into(idx, &mut scratch);
                let v = self.var_arc(*var);
                self.mem.n_stores += 1;
                self.mem.stored_bytes += v.bytes() as u64;
                self.bufs[*buf].set_arc(i, v);
            }
            Stmt::Compute { var, op, args } => {
                let vals: Vec<&Val> = args.iter().map(|a| self.var(*a)).collect();
                // Naive-baseline behavior, deliberately kept: the operator
                // is re-resolved (and any elementwise expression recompiled)
                // on every execution of the site. The compiled engine hoists
                // this into `loopir::compile`; both share `ComputeKind::
                // apply`, so numerics and flop charges stay bit-identical.
                let kind = ComputeKind::from_op(op, self.cfg);
                let mut scratch = crate::ir::exprvm::EwScratch::new();
                let (v, fl) = kind.apply(&vals, &mut scratch);
                self.mem.flops += fl;
                self.set_var(*var, Arc::new(v));
            }
            Stmt::MiscCall { tag, args, out } => {
                let f = *self
                    .cfg
                    .misc_list_ops
                    .get(tag)
                    .unwrap_or_else(|| panic!("no whole-array misc-op registered for {tag}"));
                let mut arg_vals: Vec<Vec<Val>> = Vec::with_capacity(args.len());
                for (buf, idx) in args {
                    let elems = self.gather(*buf, idx);
                    for v in &elems {
                        self.mem.n_loads += 1;
                        self.mem.loaded_bytes += v.bytes() as u64;
                    }
                    arg_vals.push(elems);
                }
                let results = f(&arg_vals);
                let (obuf, oidx) = out;
                let slots = self.scatter_slots(*obuf, oidx);
                assert_eq!(
                    results.len(),
                    slots.len(),
                    "misc op {tag} returned {} values for {} slots",
                    results.len(),
                    slots.len()
                );
                for (slot, v) in slots.into_iter().zip(results) {
                    self.mem.n_stores += 1;
                    self.mem.stored_bytes += v.bytes() as u64;
                    self.bufs[*obuf].set(&slot, v);
                }
            }
            Stmt::Accum { var, op, src } => {
                let s = self.var_arc(*src);
                let (v, fl) = accum_val(self.vars[*var].as_deref(), *op, s);
                self.mem.flops += fl;
                self.set_var(*var, v);
            }
        }
    }

    /// Row-major enumeration of the elements selected by a partial index.
    fn gather(&self, buf: usize, idx: &[Option<Index>]) -> Vec<Val> {
        let slots = self.scatter_slots(buf, idx);
        slots
            .into_iter()
            .map(|s| self.bufs[buf].get(&s).clone())
            .collect()
    }

    fn scatter_slots(&self, buf: usize, idx: &[Option<Index>]) -> Vec<Vec<usize>> {
        let dims = &self.bufs[buf].dims;
        let mut slots = vec![Vec::new()];
        for (i, s) in idx.iter().enumerate() {
            let choices: Vec<usize> = match s {
                Some(Index::Iter(d)) => vec![self.iters[d]],
                Some(Index::Zero) => vec![0],
                None => (0..dims[i]).collect(),
            };
            let mut next = Vec::with_capacity(slots.len() * choices.len());
            for base in &slots {
                for c in &choices {
                    let mut b = base.clone();
                    b.push(*c);
                    next.push(b);
                }
            }
            slots = next;
        }
        slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use crate::ir::func::FuncOp;
    use crate::ir::graph::{map_over, ArgMode, Graph};
    use crate::ir::types::Ty;
    use crate::loopir::lower::lower;
    use crate::tensor::{Mat, Rng};

    fn block_list(rng: &mut Rng, n: usize, r: usize, c: usize) -> BufVal {
        let mut bv = BufVal::new(vec![n]);
        for i in 0..n {
            bv.set(&[i], Val::Block(rng.mat(r, c)));
        }
        bv
    }

    #[test]
    fn exec_elementwise_map() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).mul(Expr::cst(2.0)), ins[0]);
            mb.collect(r);
        });
        g.output("B", o[0]);
        let ir = lower(&g);

        let mut rng = Rng::new(1);
        let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 3)]));
        let input = block_list(&mut rng, 3, 2, 2);
        cfg.inputs.insert("A".into(), input.clone());
        let res = exec(&ir, &cfg);
        let out = &res.outputs["B"];
        for i in 0..3 {
            let want = input.get(&[i]).map(|x| x * 2.0);
            assert!(out.get(&[i]).max_abs_diff(&want) < 1e-6);
        }
        // 3 loads + 3 stores of 2x2 f32 blocks
        assert_eq!(res.mem.n_loads, 3);
        assert_eq!(res.mem.n_stores, 3);
        assert_eq!(res.mem.loaded_bytes, 3 * 16);
        assert_eq!(res.mem.kernel_launches, 1);
    }

    #[test]
    fn exec_fused_reduce_resets_per_outer_iteration() {
        // forall m { for n { t += row_sum(load(A[m,n])) } store } — the
        // accumulator must reset for each m.
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["M", "N"]));
        let o = map_over(&mut g, "M", &[(a, ArgMode::Mapped)], |mb, ins| {
            let inner = map_over(&mut mb.g, "N", &[(ins[0], ArgMode::Mapped)], |mb2, ins2| {
                let r = mb2.g.func(FuncOp::RowSum, &[ins2[0]]);
                mb2.reduce_out(r, crate::ir::func::ReduceOp::Add);
            });
            mb.collect(inner[0]);
        });
        g.output("S", o[0]);
        let ir = lower(&g);

        let mut cfg = ExecConfig::new(DimSizes::of(&[("M", 2), ("N", 2)]));
        let mut bv = BufVal::new(vec![2, 2]);
        for m in 0..2 {
            for n in 0..2 {
                bv.set(
                    &[m, n],
                    Val::Block(Mat::from_vec(1, 1, vec![(m * 10 + n) as f32])),
                );
            }
        }
        cfg.inputs.insert("A".into(), bv);
        let res = exec(&ir, &cfg);
        let s = &res.outputs["S"];
        assert_eq!(s.get(&[0]).as_vector(), &[1.0]); // 0 + 1
        assert_eq!(s.get(&[1]).as_vector(), &[21.0]); // 10 + 11, NOT 22
    }

    #[test]
    fn traffic_counts_fused_vs_unfused() {
        // Unfused exp->neg materializes I1: traffic strictly larger than fused.
        let build = |fused: bool| {
            let mut g = Graph::new();
            let a = g.input("A", Ty::blocks(&["N"]));
            let o = if fused {
                map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
                    let r = mb.g.ew1(Expr::var(0).exp().neg(), ins[0]);
                    mb.collect(r);
                })
            } else {
                let o1 = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
                    let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
                    mb.collect(r);
                });
                map_over(&mut g, "N", &[(o1[0], ArgMode::Mapped)], |mb, ins| {
                    let r = mb.g.ew1(Expr::var(0).neg(), ins[0]);
                    mb.collect(r);
                })
            };
            g.output("B", o[0]);
            lower(&g)
        };
        let mut rng = Rng::new(2);
        let input = block_list(&mut rng, 4, 2, 2);
        let run = |ir: &LoopIr| {
            let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 4)]));
            cfg.inputs.insert("A".into(), input.clone());
            exec(ir, &cfg)
        };
        let unfused = run(&build(false));
        let fused = run(&build(true));
        // Same numerics…
        for i in 0..4 {
            assert!(
                unfused.outputs["B"]
                    .get(&[i])
                    .max_abs_diff(fused.outputs["B"].get(&[i]))
                    < 1e-6
            );
        }
        // …half the traffic and half the launches.
        assert_eq!(unfused.mem.total_traffic(), 2 * fused.mem.total_traffic());
        assert_eq!(unfused.mem.kernel_launches, 2);
        assert_eq!(fused.mem.kernel_launches, 1);
    }

    /// Slice attribution: executing the 4-block map as 2 slices must
    /// charge each slice exactly what a standalone 2-block run charges,
    /// while the aggregate keeps the single stacked launch.
    #[test]
    fn slice_attribution_matches_standalone_runs() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp().neg(), ins[0]);
            mb.collect(r);
        });
        g.output("B", o[0]);
        let ir = lower(&g);

        let mut rng = Rng::new(7);
        let input = block_list(&mut rng, 4, 2, 3);
        let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 4)]));
        cfg.inputs.insert("A".into(), input.clone());
        cfg.slices = Some(vec![2, 2]);
        let res = exec(&ir, &cfg);
        assert_eq!(res.per_slice.len(), 2);
        assert_eq!(res.mem.kernel_launches, 1, "one stacked launch");

        for r in 0..2usize {
            // standalone run of slice r's half of the input
            let mut half = BufVal::new(vec![2]);
            for i in 0..2 {
                half.set(&[i], input.get(&[r * 2 + i]).clone());
            }
            let mut c2 = ExecConfig::new(DimSizes::of(&[("N", 2)]));
            c2.inputs.insert("A".into(), half);
            let alone = exec(&ir, &c2);
            let s = &res.per_slice[r];
            assert_eq!(s.loaded_bytes, alone.mem.loaded_bytes, "slice {r}");
            assert_eq!(s.stored_bytes, alone.mem.stored_bytes, "slice {r}");
            assert_eq!(s.n_loads, alone.mem.n_loads, "slice {r}");
            assert_eq!(s.n_stores, alone.mem.n_stores, "slice {r}");
            assert_eq!(s.flops, alone.mem.flops, "slice {r}");
            assert_eq!(s.kernel_launches, alone.mem.kernel_launches, "slice {r}");
            // stacked output slice r equals the standalone outputs
            for i in 0..2 {
                assert_eq!(
                    res.outputs["B"].get(&[r * 2 + i]),
                    alone.outputs["B"].get(&[i]),
                    "slice {r} element {i}"
                );
            }
        }
    }

    /// Ragged slice attribution: unequal widths (including a zero-width
    /// slice) must charge each slice exactly its own iterations, leave
    /// empty slices all-zero (no launch), and keep the aggregate equal
    /// to the sum of the slices plus the single stacked launch.
    #[test]
    fn ragged_slice_widths_attribute_exactly() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp().neg(), ins[0]);
            mb.collect(r);
        });
        g.output("B", o[0]);
        let ir = lower(&g);

        let mut rng = Rng::new(11);
        let input = block_list(&mut rng, 6, 2, 3);
        let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 6)]));
        cfg.inputs.insert("A".into(), input.clone());
        cfg.slices = Some(vec![1, 0, 3, 2]);
        let res = exec(&ir, &cfg);
        assert_eq!(res.per_slice.len(), 4);
        assert_eq!(res.mem.kernel_launches, 1, "one stacked launch");
        assert_eq!(res.per_slice[1], MemSim::default(), "empty slice charges nothing");

        let mut x0 = 0usize;
        let mut summed = MemSim::default();
        for (r, &w) in [1usize, 0, 3, 2].iter().enumerate() {
            if w == 0 {
                continue;
            }
            let mut part = BufVal::new(vec![w]);
            for i in 0..w {
                part.set(&[i], input.get(&[x0 + i]).clone());
            }
            x0 += w;
            let mut c2 = ExecConfig::new(DimSizes::of(&[("N", w)]));
            c2.inputs.insert("A".into(), part);
            let alone = exec(&ir, &c2);
            let s = &res.per_slice[r];
            assert_eq!(s.loaded_bytes, alone.mem.loaded_bytes, "slice {r}");
            assert_eq!(s.stored_bytes, alone.mem.stored_bytes, "slice {r}");
            assert_eq!(s.flops, alone.mem.flops, "slice {r}");
            assert_eq!(s.kernel_launches, 1, "slice {r} pays its own launch");
            summed.add_counters(s);
        }
        assert_eq!(summed.loaded_bytes, res.mem.loaded_bytes, "slices partition the loads");
        assert_eq!(summed.stored_bytes, res.mem.stored_bytes, "slices partition the stores");
        assert_eq!(summed.flops, res.mem.flops, "slices partition the flops");
    }

    #[test]
    #[should_panic(expected = "capacity exceeded")]
    fn local_capacity_enforced() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        g.output("B", o[0]);
        let ir = lower(&g);
        let mut rng = Rng::new(3);
        let mut cfg = ExecConfig::new(DimSizes::of(&[("N", 2)]));
        cfg.inputs.insert("A".into(), block_list(&mut rng, 2, 8, 8));
        cfg.local_capacity = Some(100); // one 8x8 block = 256 bytes > 100
        let _ = exec(&ir, &cfg);
    }
}
