//! Loop IR — the executable/printable form of a block program.
//!
//! A block program lowers to a nest of `forall` (parallelizable) and `for`
//! (serial, accumulator-carrying) loops over explicit `load`/`store`
//! instructions — exactly the representation the paper uses for all of its
//! code listings. One lowering serves three purposes:
//!
//! * [`print`] renders the paper-style listings;
//! * [`interp`] executes programs on concrete data while simulating the
//!   two-tier memory (counting every global<->local transfer);
//! * [`compile`] flattens the `Stmt` tree into a linear instruction tape
//!   in two phases — a size-independent skeleton (elementwise exprs
//!   pre-compiled, every `forall` annotated for parallel safety) plus a
//!   cheap per-`DimSizes` bind of trip counts and stride tables — which
//!   `exec::engine` executes — the compile-then-execute pipeline used by
//!   the `ExecBackend::Compiled` switch;
//! * `cost` (top-level module) statically derives traffic/flops/launches.
//!
//! Buffers (`Buf`) are global-memory arrays of local items, indexed by the
//! enclosing iteration dims; vars (`VarId`) are local-memory temporaries.

pub mod compile;
pub mod interp;
pub mod lower;
pub mod print;

use crate::ir::dim::Dim;
use crate::ir::func::{FuncOp, ReduceOp};
use crate::ir::types::Item;
use std::collections::HashSet;

pub type VarId = usize;
pub type BufId = usize;

/// One index expression of a buffer access.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub enum Index {
    /// The value of the nearest enclosing loop over this dim.
    Iter(Dim),
    /// Constant 0 (Rule 7's peeled iteration).
    Zero,
}

/// A global-memory buffer declaration.
#[derive(Clone, Debug)]
pub struct BufDecl {
    pub name: String,
    pub dims: Vec<Dim>,
    pub item: Item,
    pub is_input: bool,
    pub is_output: bool,
    /// `Some(dim)` if this input is a *stateful buffer*: it persists
    /// across program invocations and is appended along `dim` each step
    /// (a KV cache; see `Graph::mark_state` in `crate::ir::graph`).
    /// Always `None` for temporaries and outputs. Execution semantics
    /// are unchanged — the tag tells the serving layer which inputs to
    /// bind from session state rather than from the request.
    pub state_dim: Option<Dim>,
}

/// Loop flavor. `ForAll` is embarrassingly parallel; `For` is serial
/// (carries accumulators — the paper's Rule 3 lowering choice).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum LoopKind {
    ForAll,
    For,
}

/// A computation op on local values.
#[derive(Clone, PartialEq, Debug)]
pub enum COp {
    Func(FuncOp),
    /// Opaque miscellaneous operator; the interpreter needs a registered
    /// callback to execute it.
    Misc(String),
}

#[derive(Clone, Debug)]
pub enum Stmt {
    Loop {
        kind: LoopKind,
        dim: Dim,
        /// Rule 7: iterate `1..X` instead of `0..X`.
        skip_first: bool,
        body: Vec<Stmt>,
        /// Vars to reset at the start of every iteration (computed by
        /// [`analyze_clears`]): everything assigned in the body except
        /// accumulators carried by this loop itself.
        clears: Vec<VarId>,
    },
    Load {
        var: VarId,
        buf: BufId,
        idx: Vec<Index>,
    },
    Store {
        var: VarId,
        buf: BufId,
        idx: Vec<Index>,
    },
    Compute {
        var: VarId,
        op: COp,
        args: Vec<VarId>,
    },
    /// `var ⊕= src` with implicit neutral-element initialization.
    Accum {
        var: VarId,
        op: ReduceOp,
        src: VarId,
    },
    /// Whole-array miscellaneous operator call (opaque kernel): reads every
    /// element of each (partially indexed) input buffer, writes every
    /// element of the output buffer. `idx` slots that are `None` range over
    /// the buffer dim; bound slots are fixed by enclosing loops.
    MiscCall {
        tag: String,
        args: Vec<(BufId, Vec<Option<Index>>)>,
        out: (BufId, Vec<Option<Index>>),
    },
}

/// A lowered block program.
#[derive(Clone, Debug, Default)]
pub struct LoopIr {
    pub bufs: Vec<BufDecl>,
    pub body: Vec<Stmt>,
    pub n_vars: usize,
    /// Named scalar parameters referenced by elementwise exprs (`DD`, `KK`).
    pub params: Vec<String>,
}

impl LoopIr {
    pub fn buf_by_name(&self, name: &str) -> Option<BufId> {
        self.bufs.iter().position(|b| b.name == name)
    }

    /// Number of top-level loop nests — the kernel-launch count of the
    /// program (each top-level operator is one kernel; opaque miscellaneous
    /// calls count as one kernel each).
    pub fn kernel_launches(&self) -> usize {
        self.body
            .iter()
            .filter(|s| matches!(s, Stmt::Loop { .. } | Stmt::MiscCall { .. }))
            .count()
    }

    /// Count of load/store instruction *sites* (static, not trip-weighted).
    pub fn transfer_sites(&self) -> (usize, usize) {
        fn walk(stmts: &[Stmt], loads: &mut usize, stores: &mut usize) {
            for s in stmts {
                match s {
                    Stmt::Load { .. } => *loads += 1,
                    Stmt::Store { .. } => *stores += 1,
                    Stmt::Loop { body, .. } => walk(body, loads, stores),
                    _ => {}
                }
            }
        }
        let (mut l, mut st) = (0, 0);
        walk(&self.body, &mut l, &mut st);
        (l, st)
    }
}

/// Compute per-loop clear sets: at the start of each iteration of a loop,
/// every var assigned anywhere in its body is reset, *except* accumulators
/// that are direct children of the loop (those carry across iterations and
/// are reset by the parent's clear instead). This encodes the paper's
/// scoping convention for `forall`/`for` listings.
pub fn analyze_clears(ir: &mut LoopIr) {
    fn assigned(stmts: &[Stmt], out: &mut HashSet<VarId>) {
        for s in stmts {
            match s {
                Stmt::Load { var, .. }
                | Stmt::Compute { var, .. }
                | Stmt::Accum { var, .. } => {
                    out.insert(*var);
                }
                Stmt::Loop { body, .. } => assigned(body, out),
                Stmt::Store { .. } | Stmt::MiscCall { .. } => {}
            }
        }
    }
    fn walk(stmts: &mut [Stmt]) {
        for s in stmts {
            if let Stmt::Loop { body, clears, .. } = s {
                let mut set = HashSet::new();
                assigned(body, &mut set);
                for child in body.iter() {
                    if let Stmt::Accum { var, .. } = child {
                        set.remove(var);
                    }
                }
                let mut v: Vec<VarId> = set.into_iter().collect();
                v.sort_unstable();
                *clears = v;
                walk(body);
            }
        }
    }
    walk(&mut ir.body);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(var: VarId) -> Stmt {
        Stmt::Load {
            var,
            buf: 0,
            idx: vec![Index::Iter(Dim::new("N"))],
        }
    }

    #[test]
    fn clears_protect_direct_accumulators() {
        let mut ir = LoopIr {
            bufs: vec![BufDecl {
                name: "A".into(),
                dims: vec![Dim::new("N")],
                item: Item::Block,
                is_input: true,
                is_output: false,
                state_dim: None,
            }],
            body: vec![Stmt::Loop {
                kind: LoopKind::For,
                dim: Dim::new("N"),
                skip_first: false,
                clears: vec![],
                body: vec![
                    load(0),
                    Stmt::Accum {
                        var: 1,
                        op: ReduceOp::Add,
                        src: 0,
                    },
                ],
            }],
            n_vars: 2,
            params: vec![],
        };
        analyze_clears(&mut ir);
        match &ir.body[0] {
            Stmt::Loop { clears, .. } => {
                assert_eq!(clears, &vec![0]); // t0 reset; accumulator t1 kept
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn nested_accumulator_cleared_by_parent() {
        // forall m { for n { t0=load; t1+=t0 } } : m-loop clears both.
        let inner = Stmt::Loop {
            kind: LoopKind::For,
            dim: Dim::new("N"),
            skip_first: false,
            clears: vec![],
            body: vec![
                load(0),
                Stmt::Accum {
                    var: 1,
                    op: ReduceOp::Add,
                    src: 0,
                },
            ],
        };
        let mut ir = LoopIr {
            bufs: vec![BufDecl {
                name: "A".into(),
                dims: vec![Dim::new("M"), Dim::new("N")],
                item: Item::Block,
                is_input: true,
                is_output: false,
                state_dim: None,
            }],
            body: vec![Stmt::Loop {
                kind: LoopKind::ForAll,
                dim: Dim::new("M"),
                skip_first: false,
                clears: vec![],
                body: vec![inner],
            }],
            n_vars: 2,
            params: vec![],
        };
        analyze_clears(&mut ir);
        match &ir.body[0] {
            Stmt::Loop { clears, .. } => assert_eq!(clears, &vec![0, 1]),
            _ => unreachable!(),
        }
    }

    #[test]
    fn launch_and_site_counts() {
        let ir = LoopIr {
            bufs: vec![],
            body: vec![
                Stmt::Loop {
                    kind: LoopKind::ForAll,
                    dim: Dim::new("M"),
                    skip_first: false,
                    clears: vec![],
                    body: vec![],
                },
                Stmt::Loop {
                    kind: LoopKind::ForAll,
                    dim: Dim::new("M"),
                    skip_first: false,
                    clears: vec![],
                    body: vec![],
                },
            ],
            n_vars: 0,
            params: vec![],
        };
        assert_eq!(ir.kernel_launches(), 2);
        assert_eq!(ir.transfer_sites(), (0, 0));
    }
}
