//! Lowering of block programs to Loop IR.
//!
//! Structure-directed: each map node becomes a loop (`forall`, or a serial
//! `for` when any output is reduced — the paper's Rule-3 lowering choice);
//! each buffered value becomes a global-memory buffer indexed by the
//! enclosing iteration dims; each unbuffered value becomes a local var.
//! Loads are emitted lazily at the first consumer in a scope and memoized,
//! so a map input merged by Rule 2 is loaded once per iteration exactly like
//! the paper's listings.

use super::{analyze_clears, BufDecl, BufId, COp, Index, LoopIr, LoopKind, Stmt, VarId};
use crate::ir::dim::Dim;
use crate::ir::func::ReduceOp;
use crate::ir::graph::{port, ArgMode, Graph, NodeId, NodeKind, OutMode, Port};
use crate::ir::types::Ty;
use std::collections::HashMap;

/// Where a graph-level value lives during lowering.
#[derive(Clone, Debug)]
enum Binding {
    /// A local var holding an item.
    Var(VarId),
    /// A global buffer; `idx[i]` is `Some` once the i-th buffer dim is bound
    /// to an index expression. Fully bound => a single item, loadable.
    Buf { buf: BufId, idx: Vec<Option<Index>> },
}

impl Binding {
    fn unbound_dims<'a>(&self, bufs: &'a [BufDecl]) -> Vec<&'a Dim> {
        match self {
            Binding::Var(_) => vec![],
            Binding::Buf { buf, idx } => idx
                .iter()
                .enumerate()
                .filter(|(_, s)| s.is_none())
                .map(|(i, _)| &bufs[*buf].dims[i])
                .collect(),
        }
    }

    /// Bind the first unbound slot whose dim equals `d`.
    fn bind_dim(&self, bufs: &[BufDecl], d: &Dim, to: Index) -> Binding {
        match self {
            Binding::Var(_) => panic!("bind_dim on a Var binding"),
            Binding::Buf { buf, idx } => {
                let decl = &bufs[*buf];
                let slot = idx
                    .iter()
                    .enumerate()
                    .position(|(i, s)| s.is_none() && decl.dims[i] == *d)
                    .unwrap_or_else(|| {
                        panic!("bind_dim: no unbound slot for {d} in buf {}", decl.name)
                    });
                let mut idx = idx.clone();
                idx[slot] = Some(to);
                Binding::Buf { buf: *buf, idx }
            }
        }
    }

    fn fully_bound(&self) -> Option<(BufId, Vec<Index>)> {
        match self {
            Binding::Var(_) => None,
            Binding::Buf { buf, idx } => {
                let mut out = Vec::with_capacity(idx.len());
                for s in idx {
                    out.push(s.clone()?);
                }
                Some((*buf, out))
            }
        }
    }
}

/// Destination for an inner-graph output / graph output value.
#[derive(Clone, Debug)]
enum OutBinding {
    /// Store elements into this partially-indexed buffer.
    Buf { buf: BufId, idx: Vec<Option<Index>> },
    /// Accumulate items into this var with this op.
    Accum(VarId, ReduceOp),
}

struct LowerState {
    bufs: Vec<BufDecl>,
    n_vars: usize,
    params: Vec<String>,
    /// Enclosing loop dims, outermost first.
    stack: Vec<Dim>,
    next_tmp_buf: usize,
}

impl LowerState {
    fn fresh_var(&mut self) -> VarId {
        self.n_vars += 1;
        self.n_vars - 1
    }

    fn fresh_buf(&mut self, dims: Vec<Dim>, item: crate::ir::types::Item) -> BufId {
        self.next_tmp_buf += 1;
        self.bufs.push(BufDecl {
            name: format!("I{}", self.next_tmp_buf),
            dims,
            item,
            is_input: false,
            is_output: false,
            state_dim: None,
        });
        self.bufs.len() - 1
    }

    fn note_params(&mut self, expr: &crate::ir::expr::Expr) {
        let mut ps = Vec::new();
        expr.params(&mut ps);
        for p in ps {
            if !self.params.contains(&p) {
                self.params.push(p);
            }
        }
    }
}

/// Per-body emission scope: statements plus a load memo so one buffer
/// element is loaded at most once per scope.
struct Scope {
    stmts: Vec<Stmt>,
    load_memo: HashMap<(BufId, Vec<Index>), VarId>,
}

impl Scope {
    fn new() -> Scope {
        Scope {
            stmts: vec![],
            load_memo: HashMap::new(),
        }
    }
}

/// Lower a top-level block program to Loop IR.
pub fn lower(g: &Graph) -> LoopIr {
    let mut st = LowerState {
        bufs: vec![],
        n_vars: 0,
        params: vec![],
        stack: vec![],
        next_tmp_buf: 0,
    };

    // Program inputs/outputs become named buffers.
    let mut in_bindings: HashMap<NodeId, Binding> = HashMap::new();
    for id in g.input_ids() {
        let ty = g.input_ty(id).clone();
        st.bufs.push(BufDecl {
            name: g.node(id).label.clone(),
            dims: ty.dims.clone(),
            item: ty.item,
            is_input: true,
            is_output: false,
            state_dim: g.state_dim(&g.node(id).label).cloned(),
        });
        let buf = st.bufs.len() - 1;
        in_bindings.insert(
            id,
            Binding::Buf {
                buf,
                idx: vec![None; ty.dims.len()],
            },
        );
    }
    let mut out_bindings: HashMap<NodeId, OutBinding> = HashMap::new();
    for id in g.output_ids() {
        let src = g
            .producer(port(id, 0))
            .unwrap_or_else(|| panic!("program output {} unconnected", g.node(id).label));
        let ty = g.out_ty(src);
        st.bufs.push(BufDecl {
            name: g.node(id).label.clone(),
            dims: ty.dims.clone(),
            item: ty.item,
            is_input: false,
            is_output: true,
            state_dim: None,
        });
        let buf = st.bufs.len() - 1;
        out_bindings.insert(
            id,
            OutBinding::Buf {
                buf,
                idx: vec![None; ty.dims.len()],
            },
        );
    }

    let mut scope = Scope::new();
    lower_graph(g, &in_bindings, &out_bindings, &mut st, &mut scope);

    let mut ir = LoopIr {
        bufs: st.bufs,
        body: scope.stmts,
        n_vars: st.n_vars,
        params: st.params,
    };
    analyze_clears(&mut ir);
    ir
}

fn lower_graph(
    g: &Graph,
    in_bindings: &HashMap<NodeId, Binding>,
    out_bindings: &HashMap<NodeId, OutBinding>,
    st: &mut LowerState,
    scope: &mut Scope,
) {
    let mut bindings: HashMap<Port, Binding> = HashMap::new();
    for (id, b) in in_bindings {
        bindings.insert(port(*id, 0), b.clone());
    }

    // Pre-scan: route values that feed Output nodes (and Concat list slots)
    // directly into their destination buffers, so producers materialize in
    // place instead of into temporaries.
    let mut out_dest: HashMap<Port, Vec<OutBinding>> = HashMap::new();
    for id in g.output_ids() {
        if let (Some(src), Some(ob)) = (g.producer(port(id, 0)), out_bindings.get(&id)) {
            // Only list-typed values benefit from routing; item values are
            // stored at the Output node itself.
            if g.out_ty(src).is_list() {
                out_dest.entry(src).or_default().push(ob.clone());
            }
        }
    }
    // Concat nodes: allocate their buffer up front and route the list input.
    let mut concat_buf: HashMap<NodeId, BufId> = HashMap::new();
    for id in g.node_ids() {
        if let NodeKind::Concat { .. } = &g.node(id).kind {
            let ty = g.out_ty(port(id, 0));
            // Reuse an Output destination if the concat feeds one directly.
            let dest = out_dest.get(&port(id, 0)).and_then(|v| {
                v.iter().find_map(|ob| match ob {
                    OutBinding::Buf { buf, idx } if idx.iter().all(|s| s.is_none()) => Some(*buf),
                    _ => None,
                })
            });
            let buf = dest.unwrap_or_else(|| {
                let mut dims = st.stack.clone();
                dims.extend(ty.dims.iter().cloned());
                st.fresh_buf(dims, ty.item)
            });
            concat_buf.insert(id, buf);
            if let Some(list_src) = g.producer(port(id, 1)) {
                let mut idx: Vec<Option<Index>> = st
                    .stack
                    .iter()
                    .map(|d| Some(Index::Iter(d.clone())))
                    .collect();
                idx.extend(std::iter::repeat(None).take(ty.dims.len()));
                out_dest
                    .entry(list_src)
                    .or_default()
                    .push(OutBinding::Buf { buf, idx });
            }
        }
    }

    for id in g.topo_order() {
        let node = g.node(id);
        match &node.kind {
            NodeKind::Input { .. } => {}
            NodeKind::Output => {
                let src = g.producer(port(id, 0)).expect("output unconnected");
                let Some(ob) = out_bindings.get(&id) else {
                    panic!("no out binding for output node {id} ({})", node.label)
                };
                let b = bindings
                    .get(&src)
                    .unwrap_or_else(|| panic!("output source {src:?} has no binding"))
                    .clone();
                emit_out(&b, ob, st, scope, g, src);
            }
            NodeKind::Func(f) => {
                let mut args = Vec::with_capacity(f.arity());
                for i in 0..f.arity() {
                    let src = g.producer(port(id, i)).expect("func input unconnected");
                    let b = bindings[&src].clone();
                    args.push(resolve_item(&b, st, scope));
                }
                if let crate::ir::func::FuncOp::Ew(e) = f {
                    st.note_params(e);
                }
                let var = st.fresh_var();
                scope.stmts.push(Stmt::Compute {
                    var,
                    op: COp::Func(f.clone()),
                    args,
                });
                bindings.insert(port(id, 0), Binding::Var(var));
            }
            NodeKind::Reduce(op) => {
                let src = g.producer(port(id, 0)).expect("reduce input unconnected");
                let b = bindings[&src].clone();
                let unbound = b.unbound_dims(&st.bufs);
                assert_eq!(
                    unbound.len(),
                    1,
                    "reduce input must be a single-level list; got {unbound:?}"
                );
                let d = unbound[0].clone();
                let bound = b.bind_dim(&st.bufs, &d, Index::Iter(d.clone()));
                let acc = st.fresh_var();
                let mut inner = Scope::new();
                st.stack.push(d.clone());
                let tmp = resolve_item(&bound, st, &mut inner);
                st.stack.pop();
                inner.stmts.push(Stmt::Accum {
                    var: acc,
                    op: *op,
                    src: tmp,
                });
                scope.stmts.push(Stmt::Loop {
                    kind: LoopKind::For,
                    dim: d,
                    skip_first: false,
                    body: inner.stmts,
                    clears: vec![],
                });
                bindings.insert(port(id, 0), Binding::Var(acc));
            }
            NodeKind::Head => {
                let src = g.producer(port(id, 0)).expect("head input unconnected");
                let b = bindings[&src].clone();
                let unbound = b.unbound_dims(&st.bufs);
                assert!(!unbound.is_empty(), "head input must be a list");
                // bind the outermost pending dim to 0
                let d = unbound[0].clone();
                let bound = b.bind_dim(&st.bufs, &d, Index::Zero);
                if unbound.len() == 1 {
                    let var = resolve_item(&bound, st, scope);
                    bindings.insert(port(id, 0), Binding::Var(var));
                } else {
                    bindings.insert(port(id, 0), bound);
                }
            }
            NodeKind::Concat { .. } => {
                let buf = concat_buf[&id];
                // Store the head item at index 0.
                let item_src = g.producer(port(id, 0)).expect("concat item unconnected");
                let item_b = bindings[&item_src].clone();
                let v = resolve_item(&item_b, st, scope);
                let mut idx: Vec<Index> = st.stack.iter().map(|d| Index::Iter(d.clone())).collect();
                idx.push(Index::Zero);
                // Elements beyond the first were routed into `buf` by the
                // producer via out_dest (skip-first map stores slots 1..X).
                scope.stmts.push(Stmt::Store { var: v, buf, idx });
                let decl_dims = st.bufs[buf].dims.len();
                let mut bidx: Vec<Option<Index>> = st
                    .stack
                    .iter()
                    .map(|d| Some(Index::Iter(d.clone())))
                    .collect();
                bidx.extend(std::iter::repeat(None).take(decl_dims - st.stack.len()));
                bindings.insert(port(id, 0), Binding::Buf { buf, idx: bidx });
            }
            NodeKind::Misc { tag, out_tys, .. } => {
                assert_eq!(out_tys.len(), 1, "misc lowering supports 1 output");
                let n_in = node.in_arity();
                let all_items = (0..n_in).all(|i| {
                    let src = g.producer(port(id, i)).expect("misc input unconnected");
                    matches!(bindings[&src], Binding::Var(_))
                        || bindings[&src].fully_bound().is_some()
                }) && !out_tys[0].is_list();
                if all_items {
                    // item-level opaque op: a plain local computation
                    let mut args = Vec::new();
                    for i in 0..n_in {
                        let src = g.producer(port(id, i)).expect("misc input unconnected");
                        args.push(resolve_item(&bindings[&src], st, scope));
                    }
                    let var = st.fresh_var();
                    scope.stmts.push(Stmt::Compute {
                        var,
                        op: COp::Misc(tag.clone()),
                        args,
                    });
                    bindings.insert(port(id, 0), Binding::Var(var));
                } else {
                    // whole-array opaque kernel
                    let mut args = Vec::new();
                    for i in 0..n_in {
                        let src = g.producer(port(id, i)).expect("misc input unconnected");
                        match bindings[&src].clone() {
                            Binding::Buf { buf, idx } => args.push((buf, idx)),
                            Binding::Var(v) => {
                                // materialize a local item so the call sees
                                // a (degenerate) buffer
                                let buf = st.fresh_buf(st.stack.clone(), out_tys[0].item);
                                let full: Vec<Index> = st
                                    .stack
                                    .iter()
                                    .map(|d| Index::Iter(d.clone()))
                                    .collect();
                                scope.stmts.push(Stmt::Store {
                                    var: v,
                                    buf,
                                    idx: full.clone(),
                                });
                                args.push((buf, full.into_iter().map(Some).collect()));
                            }
                        }
                    }
                    let out_ty = &out_tys[0];
                    let mut dims = st.stack.clone();
                    dims.extend(out_ty.dims.iter().cloned());
                    let out_buf = st.fresh_buf(dims, out_ty.item);
                    let mut out_idx: Vec<Option<Index>> = st
                        .stack
                        .iter()
                        .map(|d| Some(Index::Iter(d.clone())))
                        .collect();
                    out_idx.extend(std::iter::repeat(None).take(out_ty.dims.len()));
                    scope.stmts.push(Stmt::MiscCall {
                        tag: tag.clone(),
                        args,
                        out: (out_buf, out_idx.clone()),
                    });
                    bindings.insert(
                        port(id, 0),
                        Binding::Buf {
                            buf: out_buf,
                            idx: out_idx,
                        },
                    );
                }
            }
            NodeKind::Map(m) => {
                lower_map(g, id, m, &mut bindings, &out_dest, st, scope);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn lower_map(
    g: &Graph,
    id: NodeId,
    m: &crate::ir::graph::MapNode,
    bindings: &mut HashMap<Port, Binding>,
    out_dest: &HashMap<Port, Vec<OutBinding>>,
    st: &mut LowerState,
    scope: &mut Scope,
) {
    assert!(
        !st.stack.contains(&m.dim),
        "nested loops over the same dim {} are not supported",
        m.dim
    );
    let kind = if m.has_reduction() {
        LoopKind::For
    } else {
        LoopKind::ForAll
    };

    // Bindings for the inner graph's Input nodes.
    let mut inner_in: HashMap<NodeId, Binding> = HashMap::new();
    for (i, mi) in m.inputs.iter().enumerate() {
        let src = g
            .producer(port(id, i))
            .unwrap_or_else(|| panic!("map {id} input {i} unconnected"));
        let b = bindings
            .get(&src)
            .unwrap_or_else(|| panic!("map {id} input {i}: source {src:?} unbound"))
            .clone();
        let inner_b = match mi.mode {
            ArgMode::Mapped => b.bind_dim(&st.bufs, &m.dim, Index::Iter(m.dim.clone())),
            ArgMode::Bcast => b,
        };
        inner_in.insert(mi.inner_input, inner_b);
    }

    // Destinations for the inner graph's Output nodes.
    let mut inner_out: HashMap<NodeId, OutBinding> = HashMap::new();
    let mut post: Vec<(usize, Binding)> = Vec::new(); // (out port, post-loop binding)
    let mut extra_copies: Vec<(usize, OutBinding)> = Vec::new();
    for (j, mo) in m.outputs.iter().enumerate() {
        match &mo.mode {
            OutMode::Collect => {
                let outer_ty: Ty = g.out_ty(port(id, j));
                let dests = out_dest.get(&port(id, j));
                let primary: Option<(BufId, Vec<Option<Index>>)> =
                    dests.and_then(|v| {
                        v.iter().find_map(|ob| match ob {
                            OutBinding::Buf { buf, idx } => Some((*buf, idx.clone())),
                            _ => None,
                        })
                    });
                let had_primary = primary.is_some();
                let (buf, base_idx) = match primary {
                    Some(x) => x,
                    None => {
                        let mut dims = st.stack.clone();
                        dims.extend(outer_ty.dims.iter().cloned());
                        let buf = st.fresh_buf(dims, outer_ty.item);
                        let mut idx: Vec<Option<Index>> = st
                            .stack
                            .iter()
                            .map(|d| Some(Index::Iter(d.clone())))
                            .collect();
                        idx.extend(std::iter::repeat(None).take(outer_ty.dims.len()));
                        (buf, idx)
                    }
                };
                // Bind this map's dim slot for the inner graph.
                let inner_ob = {
                    let b = Binding::Buf {
                        buf,
                        idx: base_idx.clone(),
                    }
                    .bind_dim(&st.bufs, &m.dim, Index::Iter(m.dim.clone()));
                    match b {
                        Binding::Buf { buf, idx } => OutBinding::Buf { buf, idx },
                        _ => unreachable!(),
                    }
                };
                inner_out.insert(mo.inner_output, inner_ob);
                post.push((
                    j,
                    Binding::Buf {
                        buf,
                        idx: base_idx.clone(),
                    },
                ));
                if let Some(v) = dests {
                    for ob in v.iter().skip(if had_primary { 1 } else { 0 }) {
                        extra_copies.push((j, ob.clone()));
                    }
                }
            }
            OutMode::Reduce(op) => {
                let acc = st.fresh_var();
                inner_out.insert(mo.inner_output, OutBinding::Accum(acc, *op));
                post.push((j, Binding::Var(acc)));
            }
        }
    }

    // Lower the inner graph inside the loop.
    let mut inner_scope = Scope::new();
    st.stack.push(m.dim.clone());
    lower_graph(&m.inner, &inner_in, &inner_out, st, &mut inner_scope);
    st.stack.pop();
    scope.stmts.push(Stmt::Loop {
        kind,
        dim: m.dim.clone(),
        skip_first: m.skip_first,
        body: inner_scope.stmts,
        clears: vec![],
    });

    for (j, b) in post {
        bindings.insert(port(id, j), b);
    }
    // Rare: a collect output feeding multiple Output nodes — copy.
    for (j, ob) in extra_copies {
        let b = bindings[&port(id, j)].clone();
        emit_copy_list(&b, &ob, st, scope);
    }
}

/// Emit the value behind `b` as a local var (loading from global memory if
/// necessary, with per-scope memoization).
fn resolve_item(b: &Binding, st: &mut LowerState, scope: &mut Scope) -> VarId {
    match b {
        Binding::Var(v) => *v,
        Binding::Buf { .. } => {
            let (buf, idx) = b.fully_bound().unwrap_or_else(|| {
                panic!(
                    "resolve_item: binding not fully bound: {:?} (unbound {:?})",
                    b,
                    b.unbound_dims(&st.bufs)
                )
            });
            if let Some(v) = scope.load_memo.get(&(buf, idx.clone())) {
                return *v;
            }
            let var = st.fresh_var();
            scope.stmts.push(Stmt::Load {
                var,
                buf,
                idx: idx.clone(),
            });
            scope.load_memo.insert((buf, idx), var);
            var
        }
    }
}

/// Emit the graph-output handling for a produced value.
fn emit_out(
    b: &Binding,
    ob: &OutBinding,
    st: &mut LowerState,
    scope: &mut Scope,
    g: &Graph,
    src: Port,
) {
    match (b, ob) {
        (Binding::Var(v), OutBinding::Buf { buf, idx }) => {
            let full: Vec<Index> = idx
                .iter()
                .map(|s| s.clone().expect("item store into unbound buffer slot"))
                .collect();
            scope.stmts.push(Stmt::Store {
                var: *v,
                buf: *buf,
                idx: full,
            });
        }
        (Binding::Var(v), OutBinding::Accum(acc, op)) => {
            scope.stmts.push(Stmt::Accum {
                var: *acc,
                op: *op,
                src: *v,
            });
        }
        (Binding::Buf { buf, .. }, OutBinding::Buf { buf: dest, .. }) if buf == dest => {
            // Already materialized in place via out_dest routing.
        }
        (Binding::Buf { .. }, OutBinding::Buf { .. }) => {
            // Producer materialized elsewhere (e.g. pass-through of an
            // input): copy element-by-element.
            let _ = g;
            let _ = src;
            emit_copy_list(b, ob, st, scope);
        }
        (Binding::Buf { .. }, OutBinding::Accum(..)) => {
            panic!("list value cannot feed an accumulating output")
        }
    }
}

/// Copy a (possibly partially bound) list value into a destination buffer,
/// looping over the unbound dims.
fn emit_copy_list(b: &Binding, ob: &OutBinding, st: &mut LowerState, scope: &mut Scope) {
    let OutBinding::Buf {
        buf: dest,
        idx: dest_idx,
    } = ob
    else {
        panic!("emit_copy_list: non-buffer destination");
    };
    let unbound: Vec<Dim> = b
        .unbound_dims(&st.bufs)
        .into_iter()
        .cloned()
        .collect();
    fn rec(
        b: &Binding,
        dest: BufId,
        dest_idx: &[Option<Index>],
        rest: &[Dim],
        st: &mut LowerState,
        scope: &mut Scope,
    ) {
        match rest.split_first() {
            None => {
                let v = resolve_item(b, st, scope);
                let full: Vec<Index> = dest_idx
                    .iter()
                    .map(|s| s.clone().expect("copy: unbound dest slot"))
                    .collect();
                scope.stmts.push(Stmt::Store {
                    var: v,
                    buf: dest,
                    idx: full,
                });
            }
            Some((d, more)) => {
                let bb = b.bind_dim(&st.bufs, d, Index::Iter(d.clone()));
                // bind the matching dest slot
                let mut di = dest_idx.to_vec();
                let decl = &st.bufs[dest];
                if let Some(slot) = di
                    .iter()
                    .enumerate()
                    .position(|(i, s)| s.is_none() && decl.dims[i] == *d)
                {
                    di[slot] = Some(Index::Iter(d.clone()));
                }
                let mut inner = Scope::new();
                st.stack.push(d.clone());
                rec(&bb, dest, &di, more, st, &mut inner);
                st.stack.pop();
                scope.stmts.push(Stmt::Loop {
                    kind: LoopKind::ForAll,
                    dim: d.clone(),
                    skip_first: false,
                    body: inner.stmts,
                    clears: vec![],
                });
            }
        }
    }
    rec(b, *dest, dest_idx, &unbound, st, scope);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use crate::ir::func::FuncOp;
    use crate::ir::graph::{map_over, ArgMode, Graph};
    use crate::ir::types::Ty;

    /// §2.1 example: `forall n: a = load(A[n]); b = (a-s)/d; store(b, B[n])`.
    #[test]
    fn lower_simple_map() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let e = Expr::var(0).sub(Expr::cst(1.0)).div(Expr::cst(2.0));
            let r = mb.g.ew1(e, ins[0]);
            mb.collect(r);
        });
        g.output("B", o[0]);
        let ir = lower(&g);
        assert_eq!(ir.bufs.len(), 2); // A and B, no temporaries
        assert_eq!(ir.kernel_launches(), 1);
        assert_eq!(ir.transfer_sites(), (1, 1));
        match &ir.body[0] {
            Stmt::Loop { kind, dim, body, .. } => {
                assert_eq!(*kind, LoopKind::ForAll);
                assert_eq!(dim.name(), "N");
                assert_eq!(body.len(), 3); // load, compute, store
            }
            other => panic!("expected loop, got {other:?}"),
        }
    }

    /// Chained maps materialize an interior temporary buffer I1.
    #[test]
    fn lower_chained_maps_materializes() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o1 = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        let o2 = map_over(&mut g, "N", &[(o1[0], ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).neg(), ins[0]);
            mb.collect(r);
        });
        g.output("B", o2[0]);
        let ir = lower(&g);
        assert_eq!(ir.bufs.len(), 3); // A, B, I1
        assert!(ir.bufs.iter().any(|b| b.name == "I1"));
        assert_eq!(ir.kernel_launches(), 2);
    }

    /// Map + reduction node: serial loop with accumulator.
    #[test]
    fn lower_reduce_node() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.collect(r);
        });
        let red = g.reduce(ReduceOp::Add, o[0]);
        g.output("c", red);
        let ir = lower(&g);
        // forall n {load, rowsum, store I1}; for n {load, accum}; store c
        assert_eq!(ir.kernel_launches(), 2);
        let has_for = ir
            .body
            .iter()
            .any(|s| matches!(s, Stmt::Loop { kind: LoopKind::For, .. }));
        assert!(has_for);
        assert!(matches!(ir.body.last(), Some(Stmt::Store { .. })));
    }

    /// Reduced map output: single serial loop, no temporary buffer.
    #[test]
    fn lower_fused_map_reduce() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            mb.reduce_out(r, ReduceOp::Add);
        });
        g.output("c", o[0]);
        let ir = lower(&g);
        assert_eq!(ir.bufs.len(), 2); // A, c only
        assert_eq!(ir.kernel_launches(), 1);
        match &ir.body[0] {
            Stmt::Loop { kind, .. } => assert_eq!(*kind, LoopKind::For),
            other => panic!("expected loop, got {other:?}"),
        }
    }

    /// Shared map input is loaded once per iteration (Rule-2 merge effect).
    #[test]
    fn shared_input_single_load() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let o = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let x = mb.g.func(FuncOp::RowSum, &[ins[0]]);
            let y = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            let z = mb.g.func(FuncOp::RowScale, &[y, x]);
            mb.collect(z);
        });
        g.output("B", o[0]);
        let ir = lower(&g);
        assert_eq!(ir.transfer_sites().0, 1, "A loaded once per iteration");
    }

    /// Output buffer is written in place (no extra temp + copy).
    #[test]
    fn collect_routes_to_output_buffer() {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["M", "N"]));
        let o = map_over(&mut g, "M", &[(a, ArgMode::Mapped)], |mb, ins| {
            let inner = map_over(&mut mb.g, "N", &[(ins[0], ArgMode::Mapped)], |mb2, ins2| {
                let r = mb2.g.ew1(Expr::var(0).exp(), ins2[0]);
                mb2.collect(r);
            });
            mb.collect(inner[0]);
        });
        g.output("B", o[0]);
        let ir = lower(&g);
        assert_eq!(ir.bufs.len(), 2, "no temporaries: {:?}", ir.bufs);
        // store goes directly into B with idx [m, n]
        fn find_store(stmts: &[Stmt]) -> Option<(BufId, Vec<Index>)> {
            for s in stmts {
                match s {
                    Stmt::Store { buf, idx, .. } => return Some((*buf, idx.clone())),
                    Stmt::Loop { body, .. } => {
                        if let Some(x) = find_store(body) {
                            return Some(x);
                        }
                    }
                    _ => {}
                }
            }
            None
        }
        let (buf, idx) = find_store(&ir.body).unwrap();
        assert_eq!(ir.bufs[buf].name, "B");
        assert_eq!(
            idx,
            vec![
                Index::Iter(Dim::new("M")),
                Index::Iter(Dim::new("N"))
            ]
        );
    }
}
