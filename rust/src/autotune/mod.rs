//! Block-shape autotuner.
//!
//! §2.1: "The number of blocks along each dimension is a parameter, which
//! can later be optimized using an auto-tuning procedure", and the example
//! epilogues rely on it: Flash Attention is recovered by the autotuner
//! "setting D = L = 1", and the RMSNorm+FFN-SwiGLU mega-kernel's redundant
//! work "disappears" at N = K = 1 if local memory allows, with the autotuner
//! balancing replication against block size otherwise.
//!
//! The tuner enumerates block-count assignments (divisors of the full dim
//! sizes), scores each with the static cost model, and filters assignments
//! whose estimated peak local-memory footprint exceeds the machine's local
//! capacity. A convenient property exploited here (§1): fusion decisions do
//! not depend on block shapes, so the program is fused once and re-costed
//! many times.

use crate::cost::{analyze, Cost, CostModel, ShapeEnv};
use crate::exec::{run_lowered_cached, ExecBackend, TapeCache, Workload};
use crate::ir::dim::{Dim, DimSizes};
use crate::ir::graph::Graph;
use crate::loopir::interp::MemSim;
use crate::loopir::lower::lower;
use crate::loopir::LoopIr;
use crate::tensor::Mat;
use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

/// One scored configuration.
#[derive(Clone, Debug)]
pub struct TunePoint {
    pub sizes: DimSizes,
    pub cost: Cost,
    pub scalar: f64,
    pub feasible: bool,
}

/// Autotuning result: all evaluated points, best first among feasible.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub points: Vec<TunePoint>,
}

impl TuneResult {
    pub fn best(&self) -> Option<&TunePoint> {
        self.points.iter().find(|p| p.feasible)
    }
}

/// All divisors of `n`, ascending.
pub fn divisors(n: usize) -> Vec<usize> {
    (1..=n).filter(|d| n % d == 0).collect()
}

/// Enumerate block-count assignments for `dims`, where each dim's count
/// must divide every full extent it blocks.
fn dim_domains(
    ir: &LoopIr,
    full: &HashMap<String, (usize, usize)>,
) -> Vec<(Dim, Vec<usize>)> {
    // collect, per dim, the set of full extents it must divide
    let mut extents: HashMap<Dim, Vec<usize>> = HashMap::new();
    for b in &ir.bufs {
        if !b.is_input {
            continue;
        }
        let (r, c) = full[&b.name];
        for (d, ext) in b.dims.iter().zip([r, c]) {
            extents.entry(d.clone()).or_default().push(ext);
        }
    }
    // every dim appearing anywhere in the program must get a size; dims not
    // constrained by inputs inherit the constraint of same-named use later
    let mut all_dims: Vec<Dim> = Vec::new();
    fn dims_of(stmts: &[crate::loopir::Stmt], out: &mut Vec<Dim>) {
        for s in stmts {
            if let crate::loopir::Stmt::Loop { dim, body, .. } = s {
                if !out.contains(dim) {
                    out.push(dim.clone());
                }
                dims_of(body, out);
            }
        }
    }
    dims_of(&ir.body, &mut all_dims);
    for b in &ir.bufs {
        for d in &b.dims {
            if !all_dims.contains(d) {
                all_dims.push(d.clone());
            }
        }
    }

    all_dims
        .into_iter()
        .map(|d| {
            let dom = match extents.get(&d) {
                Some(exts) => {
                    let mut common: Vec<usize> = divisors(exts[0]);
                    common.retain(|x| exts.iter().all(|e| e % x == 0));
                    common
                }
                None => vec![1],
            };
            (d, dom)
        })
        .collect()
}

/// Exhaustively tune block counts for a (typically fused) block program.
pub fn autotune(
    g: &Graph,
    full: &HashMap<String, (usize, usize)>,
    local_capacity: u64,
    model: &CostModel,
) -> TuneResult {
    autotune_ir(&lower(g), full, local_capacity, model)
}

/// Same, over an already-lowered program (lets callers that also execute
/// the IR — `autotune_measured` — lower once).
pub fn autotune_ir(
    ir: &LoopIr,
    full: &HashMap<String, (usize, usize)>,
    local_capacity: u64,
    model: &CostModel,
) -> TuneResult {
    let domains = dim_domains(ir, full);
    let mut points = Vec::new();
    let mut idx = vec![0usize; domains.len()];
    loop {
        let mut sizes = DimSizes::new();
        for (k, (d, dom)) in domains.iter().enumerate() {
            sizes.set(d.clone(), dom[idx[k]]);
        }
        let env = ShapeEnv::from_full_shapes(ir, &sizes, full);
        let cost = analyze(ir, &sizes, &env);
        let feasible = cost.peak_local_bytes <= local_capacity;
        points.push(TunePoint {
            scalar: model.scalar(&cost),
            sizes,
            cost,
            feasible,
        });
        // next index vector
        let mut k = 0;
        loop {
            if k == domains.len() {
                let mut sorted = points;
                sorted.sort_by(|a, b| {
                    b.feasible
                        .cmp(&a.feasible)
                        .then(a.scalar.partial_cmp(&b.scalar).unwrap())
                });
                return TuneResult { points: sorted };
            }
            idx[k] += 1;
            if idx[k] < domains[k].1.len() {
                break;
            }
            idx[k] = 0;
            k += 1;
        }
    }
}

/// A statically-ranked candidate validated by real execution.
#[derive(Clone, Debug)]
pub struct MeasuredPoint {
    pub sizes: DimSizes,
    pub wall_ns: u128,
    pub mem: MemSim,
    pub static_scalar: f64,
}

/// Execute the top-`trials` statically-ranked feasible configurations on
/// real data and re-rank them by measured wall-clock (best first).
///
/// Autotune trials are the hottest caller of the executor, so this is
/// where the [`ExecBackend`] switch matters most: with
/// [`ExecBackend::Compiled`] the program structure is compiled **once**
/// into a size-independent tape skeleton (shared across trials through a
/// [`TapeCache`]) and each candidate only re-binds trip counts and
/// stride tables before running with SIMD kernels and multi-threaded
/// grid loops — instead of tree-walking the `Stmt` nest per trial.
#[allow(clippy::too_many_arguments)]
pub fn autotune_measured(
    g: &Graph,
    full: &HashMap<String, (usize, usize)>,
    local_capacity: u64,
    model: &CostModel,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
    backend: ExecBackend,
    trials: usize,
) -> Vec<MeasuredPoint> {
    autotune_measured_opts(g, full, local_capacity, model, params, inputs, backend, trials, None)
}

/// [`autotune_measured`] plus a worker cap for the compiled engine's
/// parallel grid loops (the CLI's `--threads`): measured trials should
/// run under the same worker budget the tuned program will deploy with,
/// or the measured ranking optimizes for the wrong machine shape.
#[allow(clippy::too_many_arguments)]
pub fn autotune_measured_opts(
    g: &Graph,
    full: &HashMap<String, (usize, usize)>,
    local_capacity: u64,
    model: &CostModel,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
    backend: ExecBackend,
    trials: usize,
    threads: Option<usize>,
) -> Vec<MeasuredPoint> {
    let mut cache = TapeCache::new();
    autotune_measured_cached(
        g,
        full,
        local_capacity,
        model,
        params,
        inputs,
        backend,
        trials,
        threads,
        &mut cache,
    )
}

/// [`autotune_measured_opts`] with a caller-owned [`TapeCache`], so
/// long-lived hosts (the serving layer's `tune`) share one skeleton
/// cache between serving traffic and measured trials — a re-tune of an
/// already-cached structure compiles nothing.
#[allow(clippy::too_many_arguments)]
pub fn autotune_measured_cached(
    g: &Graph,
    full: &HashMap<String, (usize, usize)>,
    local_capacity: u64,
    model: &CostModel,
    params: &BTreeMap<String, f32>,
    inputs: &HashMap<String, Mat>,
    backend: ExecBackend,
    trials: usize,
    threads: Option<usize>,
    cache: &mut TapeCache,
) -> Vec<MeasuredPoint> {
    let ir = lower(g);
    let static_rank = autotune_ir(&ir, full, local_capacity, model);
    // one workload shared across trials (inputs can be large); only the
    // block-count assignment changes per candidate. No capacity assertion:
    // static feasibility is an approximation, not a hard runtime bound.
    let mut w = Workload {
        sizes: DimSizes::new(),
        params: params.clone(),
        inputs: inputs.clone(),
        local_capacity: None,
        threads,
    };
    let misses_before = cache.misses;
    let mut out = Vec::new();
    for p in static_rank.points.iter().filter(|p| p.feasible).take(trials) {
        w.sizes = p.sizes.clone();
        let t0 = Instant::now();
        let run = run_lowered_cached(&ir, &w, backend, cache);
        out.push(MeasuredPoint {
            sizes: p.sizes.clone(),
            wall_ns: t0.elapsed().as_nanos(),
            mem: run.mem,
            static_scalar: p.scalar,
        });
    }
    debug_assert!(
        backend != ExecBackend::Compiled || cache.misses - misses_before <= 1,
        "all trials share one program structure"
    );
    out.sort_by_key(|m| m.wall_ns);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::programs;
    use crate::fusion::fuse;
    use crate::lower::lower_array;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
    }

    /// The FA epilogue: with ample local memory, the autotuner sets
    /// D = L = 1 (whole rows of Q and whole columns of V in local memory),
    /// which "reproduces the original Flash Attention kernel".
    #[test]
    fn attention_tuner_picks_d_l_one() {
        let g = lower_array(&programs::attention());
        let fused = fuse(g).snapshots.pop().unwrap();
        let mut full = HashMap::new();
        full.insert("Q".to_string(), (64, 32));
        full.insert("KT".to_string(), (64, 32));
        full.insert("VT".to_string(), (32, 64));
        let res = autotune(&fused, &full, 1 << 20, &CostModel::default());
        let best = res.best().expect("some feasible point");
        assert_eq!(best.sizes.get(&Dim::new("D")), 1, "best: {best:?}");
        assert_eq!(best.sizes.get(&Dim::new("L")), 1, "best: {best:?}");
    }

    /// With a tiny local memory, single-block configurations become
    /// infeasible and the tuner must pick more blocks.
    #[test]
    fn capacity_forces_more_blocks() {
        let g = lower_array(&programs::attention());
        let fused = fuse(g).snapshots.pop().unwrap();
        let mut full = HashMap::new();
        full.insert("Q".to_string(), (64, 32));
        full.insert("KT".to_string(), (64, 32));
        full.insert("VT".to_string(), (32, 64));
        let roomy = autotune(&fused, &full, 1 << 20, &CostModel::default());
        let tight = autotune(&fused, &full, 6 << 10, &CostModel::default());
        let rb = roomy.best().unwrap();
        let tb = tight.best().expect("some feasible point under 6KiB");
        assert!(tb.cost.peak_local_bytes <= 6 << 10);
        let blocks = |p: &TunePoint| {
            p.sizes.0.values().product::<usize>()
        };
        assert!(
            blocks(tb) > blocks(rb),
            "tight {:?} vs roomy {:?}",
            tb.sizes,
            rb.sizes
        );
        // feasibility is honored in ranking: every feasible point precedes
        // every infeasible one
        let first_infeasible = tight.points.iter().position(|p| !p.feasible);
        if let Some(fi) = first_infeasible {
            assert!(tight.points[..fi].iter().all(|p| p.feasible));
        }
    }

    /// Measured trials: same candidates, identical simulated counters on
    /// both backends (the tape engine is bit-compatible), non-empty result.
    #[test]
    fn measured_trials_agree_across_backends() {
        let g = lower_array(&programs::attention());
        let fused = fuse(g).snapshots.pop().unwrap();
        let mut full = HashMap::new();
        full.insert("Q".to_string(), (32, 16));
        full.insert("KT".to_string(), (32, 16));
        full.insert("VT".to_string(), (16, 32));
        let mut rng = crate::tensor::Rng::new(5);
        let mut inputs = HashMap::new();
        for (n, (r, c)) in &full {
            inputs.insert(n.clone(), rng.mat(*r, *c));
        }
        let mut params = BTreeMap::new();
        params.insert("DD".to_string(), 16.0);
        let model = CostModel::default();
        let run = |backend| {
            autotune_measured(
                &fused, &full, 1 << 20, &model, &params, &inputs, backend, 3,
            )
        };
        let mi = run(ExecBackend::Interp);
        let mc = run(ExecBackend::Compiled);
        assert_eq!(mi.len(), 3);
        assert_eq!(mc.len(), 3);
        let digest = |ms: &[MeasuredPoint]| {
            let mut v: Vec<String> = ms
                .iter()
                .map(|m| {
                    format!(
                        "{:?} l={} s={} f={} k={}",
                        m.sizes.0,
                        m.mem.loaded_bytes,
                        m.mem.stored_bytes,
                        m.mem.flops,
                        m.mem.kernel_launches
                    )
                })
                .collect();
            v.sort();
            v
        };
        assert_eq!(digest(&mi), digest(&mc));
    }

    /// The RMS+FFN epilogue: at N = K = 1 "all the redundant work
    /// disappears" — flops at (N=1, K=1) must equal the unreplicated
    /// snapshot's flops, and larger N/K must replicate (more flops).
    #[test]
    fn rms_ffn_replication_vanishes_at_n1_k1() {
        let g = lower_array(&programs::rmsnorm_ffn_swiglu());
        let res = fuse(g);
        let unreplicated = &res.snapshots[0];
        let mega = res.snapshots.last().unwrap();
        let mut full = HashMap::new();
        full.insert("X".to_string(), (16, 32));
        full.insert("WT".to_string(), (32, 32));
        full.insert("VT".to_string(), (32, 32));
        full.insert("UT".to_string(), (16, 32));

        let cost_at = |g: &Graph, m: usize, d: usize, k: usize, n: usize| {
            let sizes = DimSizes::of(&[("M", m), ("D", d), ("K", k), ("N", n)]);
            let ir = lower(g);
            let env = ShapeEnv::from_full_shapes(&ir, &sizes, &full);
            analyze(&ir, &sizes, &env)
        };
        let mega11 = cost_at(mega, 4, 2, 1, 1);
        let flat11 = cost_at(unreplicated, 4, 2, 1, 1);
        assert_eq!(mega11.flops, flat11.flops, "no replication at N=K=1");
        let mega22 = cost_at(mega, 4, 2, 2, 2);
        assert!(
            mega22.flops > mega11.flops,
            "replication must grow with N,K"
        );
    }
}
