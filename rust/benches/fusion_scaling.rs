//! Bench: fusion-algorithm scalability — wall-clock and step count vs
//! program size (the paper motivates the two-algorithm structure as
//! "especially suitable for large AI programs", e.g. an entire decoder
//! block; here we stack alternating LayerNorm+Matmul layers).

use blockbuster::array::ArrayProgram;
use blockbuster::fusion::fuse;
use blockbuster::lower::lower_array;
use blockbuster::util::bench::{bench, fmt_stat, Table};
use std::time::Duration;

/// An n-layer MLP-with-norms chain: X -> [layernorm -> matmul] × n, the
/// contraction dim alternating between K and P.
fn stacked(n_layers: usize) -> ArrayProgram {
    let mut p = ArrayProgram::new();
    let mut cur = p.input("X", "M", "K");
    for i in 0..n_layers {
        let (from, to) = if i % 2 == 0 { ("K", "P") } else { ("P", "K") };
        let w = p.input_t(&format!("W{i}"), to, from);
        let ln = p.layernorm(cur);
        cur = p.matmul(ln, w);
    }
    p.output("Y", cur);
    p
}

fn main() {
    let mut t = Table::new(
        "Fusion algorithm scaling (stacked layernorm+matmul layers)",
        &[
            "layers",
            "array ops",
            "block nodes",
            "steps",
            "fuse time",
            "ns/step",
        ],
    );
    for layers in [1usize, 2, 4, 8, 12, 16] {
        let p = stacked(layers);
        let g = lower_array(&p);
        let nodes = g.node_count_recursive();
        let res = fuse(g.clone());
        let stats = bench(3, Duration::from_millis(1200), || fuse(g.clone()));
        t.row(vec![
            layers.to_string(),
            p.op_count().to_string(),
            nodes.to_string(),
            res.trace.len().to_string(),
            fmt_stat(&stats),
            format!("{:.0}", stats.median_ns / res.trace.len() as f64),
        ]);
    }
    t.print();
}
