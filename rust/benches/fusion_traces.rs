//! Bench: regenerate the paper's §5 fusion traces (Examples 1–3).
//!
//! Emits one table row per example: paper step count vs ours, per-rule
//! application counts, snapshot count, final buffered-edge census, and the
//! fusion algorithm's wall-clock.

use blockbuster::array::programs;
use blockbuster::fusion::fuse;
use blockbuster::lower::lower_array;
use blockbuster::util::bench::{fmt_stat, quick, Table};

fn main() {
    let cases: Vec<(&str, usize, blockbuster::array::ArrayProgram)> = vec![
        ("Example 1: Flash Attention", 17, programs::attention()),
        ("Example 2: LayerNorm+Matmul", 22, programs::layernorm_matmul()),
        ("Example 3: RMSNorm+FFN-SwiGLU", 26, programs::rmsnorm_ffn_swiglu()),
        ("§1: Matmul+ReLU", 0, programs::matmul_relu()),
        ("e2e: decoder block", 0, programs::decoder_block()),
    ];

    let mut t = Table::new(
        "Paper §5 fusion traces (steps: paper vs reproduced)",
        &[
            "example",
            "paper",
            "ours",
            "rules",
            "snaps",
            "interior-edges",
            "fuse time",
        ],
    );
    for (name, paper_steps, p) in &cases {
        let g = lower_array(p);
        let res = fuse(g.clone());
        let stats = quick(|| fuse(g.clone()));
        t.row(vec![
            name.to_string(),
            if *paper_steps > 0 {
                paper_steps.to_string()
            } else {
                "—".into()
            },
            res.trace.len().to_string(),
            res.trace.summary(),
            res.snapshots.len().to_string(),
            format!(
                "{} -> {}",
                g.interior_buffered_count_recursive(),
                res.snapshots
                    .last()
                    .unwrap()
                    .interior_buffered_count_recursive()
            ),
            fmt_stat(&stats),
        ]);
    }
    t.print();

    println!("\nFull Example-1 trace (compare with the paper's Steps 1-17):");
    let res = fuse(lower_array(&programs::attention()));
    print!("{}", res.trace);
}
