//! Bench: ablations the paper's epilogues call for.
//!
//! 1. Flash-Attention autotuning under shrinking local-memory capacities —
//!    D = L = 1 when memory allows (recovering original Flash Attention),
//!    more blocks as capacity tightens.
//! 2. RMSNorm+FFN-SwiGLU replication trade-off: traffic vs redundant flops
//!    across (K, N) block counts for the mega-kernel vs the unreplicated
//!    snapshot — the decision the selection/autotuning layer settles.
//! 3. Rule 6 (extend, replicates work) vs Rule 7 (peel, no replication) on
//!    the canonical extendable program.

use blockbuster::array::programs;
use blockbuster::autotune::autotune;
use blockbuster::cost::{analyze, CostModel, ShapeEnv};
use blockbuster::fusion::fuse;
use blockbuster::ir::dim::DimSizes;
use blockbuster::loopir::lower::lower;
use blockbuster::lower::lower_array;
use blockbuster::util::bench::{fmt_bytes, Table};
use std::collections::HashMap;

fn main() {
    attention_capacity_sweep();
    rms_replication_tradeoff();
    rule6_vs_rule7();
}

fn attention_capacity_sweep() {
    let fused = fuse(lower_array(&programs::attention()))
        .snapshots
        .pop()
        .unwrap();
    let mut full = HashMap::new();
    full.insert("Q".to_string(), (64, 32));
    full.insert("KT".to_string(), (64, 32));
    full.insert("VT".to_string(), (32, 64));
    let mut t = Table::new(
        "Flash Attention: autotuned block counts vs local-memory capacity",
        &["capacity", "best sizes", "traffic", "peak local", "feasible pts"],
    );
    for cap in [1u64 << 20, 64 << 10, 32 << 10, 16 << 10, 8 << 10] {
        let res = autotune(&fused, &full, cap, &CostModel::default());
        let nf = res.points.iter().filter(|p| p.feasible).count();
        match res.best() {
            Some(b) => t.row(vec![
                fmt_bytes(cap),
                format!("{:?}", b.sizes.0),
                fmt_bytes(b.cost.traffic()),
                fmt_bytes(b.cost.peak_local_bytes),
                nf.to_string(),
            ]),
            None => t.row(vec![
                fmt_bytes(cap),
                "(none feasible)".into(),
                "—".into(),
                "—".into(),
                "0".into(),
            ]),
        }
    }
    t.print();
}

fn rms_replication_tradeoff() {
    let res = fuse(lower_array(&programs::rmsnorm_ffn_swiglu()));
    let flat = &res.snapshots[0];
    let mega = res.snapshots.last().unwrap();
    let mut full = HashMap::new();
    full.insert("X".to_string(), (16, 32));
    full.insert("WT".to_string(), (32, 32));
    full.insert("VT".to_string(), (32, 32));
    full.insert("UT".to_string(), (16, 32));
    let cost = |g, k, n| {
        let sizes = DimSizes::of(&[("M", 4), ("D", 2), ("K", k), ("N", n)]);
        let ir = lower(g);
        let env = ShapeEnv::from_full_shapes(&ir, &sizes, &full);
        analyze(&ir, &sizes, &env)
    };
    let mut t = Table::new(
        "RMSNorm+FFN-SwiGLU: mega-kernel replication vs block counts (paper epilogue)",
        &[
            "K,N",
            "mega traffic",
            "mega flops",
            "flat traffic",
            "flat flops",
            "redundant",
            "mega peak-local",
        ],
    );
    for (k, n) in [(1, 1), (2, 1), (4, 1), (1, 2), (2, 2), (4, 2), (4, 4)] {
        let cm = cost(mega, k, n);
        let cf = cost(flat, k, n);
        t.row(vec![
            format!("{k},{n}"),
            fmt_bytes(cm.traffic()),
            cm.flops.to_string(),
            fmt_bytes(cf.traffic()),
            cf.flops.to_string(),
            format!("{:+.0}%", 100.0 * (cm.flops as f64 / cf.flops as f64 - 1.0)),
            fmt_bytes(cm.peak_local_bytes),
        ]);
    }
    t.print();
}

fn rule6_vs_rule7() {
    use blockbuster::ir::expr::Expr;
    use blockbuster::ir::func::{FuncOp, ReduceOp};
    use blockbuster::ir::graph::{map_over, ArgMode, Graph};
    use blockbuster::ir::types::Ty;

    // the canonical extendable shape: exp-map feeding a dot+reduce L-map
    let build = || {
        let mut g = Graph::new();
        let a = g.input("A", Ty::blocks(&["N"]));
        let vt = g.input("VT", Ty::blocks(&["L", "N"]));
        let u = map_over(&mut g, "N", &[(a, ArgMode::Mapped)], |mb, ins| {
            let r = mb.g.ew1(Expr::var(0).exp(), ins[0]);
            mb.collect(r);
        });
        let x = map_over(
            &mut g,
            "L",
            &[(u[0], ArgMode::Bcast), (vt, ArgMode::Mapped)],
            |mb, ins| {
                let inner = map_over(
                    &mut mb.g,
                    "N",
                    &[(ins[0], ArgMode::Mapped), (ins[1], ArgMode::Mapped)],
                    |mb2, i2| {
                        let d = mb2.g.func(FuncOp::Dot, &[i2[0], i2[1]]);
                        mb2.collect(d);
                    },
                );
                let red = mb.g.reduce(ReduceOp::Add, inner[0]);
                mb.collect(red);
            },
        );
        g.output("O", x[0]);
        g
    };

    // A is 1-d blocked here, so build the shape env by hand:
    // A: 4 blocks of (8, 32); VT: 8x4 blocks of (8, 8).
    let sizes = DimSizes::of(&[("N", 4), ("L", 8)]);
    let cost = |g: &Graph| {
        let ir = lower(g);
        let mut env = ShapeEnv::default();
        env.inputs
            .insert("A".to_string(), blockbuster::cost::VShape::Block(8, 32));
        env.inputs
            .insert("VT".to_string(), blockbuster::cost::VShape::Block(8, 32));
        analyze(&ir, &sizes, &env)
    };

    let base = build();
    let mut extended = build();
    blockbuster::rules::rule6::try_rule6(&mut extended).expect("rule 6 applies");
    // fuse the exposed opportunity
    while blockbuster::rules::rule1::try_rule1(
        &mut extended
            .node_mut(blockbuster::rules::map_ids(&extended)[0])
            .as_map_mut()
            .unwrap()
            .inner,
    )
    .is_some()
    {}
    let mut peeled = build();
    blockbuster::rules::rule7::try_rule7(&mut peeled).expect("rule 7 applies");

    let mut t = Table::new(
        "Companion-rule ablation: Rule 6 (extend) vs Rule 7 (peel)",
        &["variant", "traffic", "flops", "launches", "interior edges"],
    );
    for (name, g) in [
        ("baseline (no companion rule)", &base),
        ("rule 6: extend + fuse", &extended),
        ("rule 7: peel first iteration", &peeled),
    ] {
        let c = cost(g);
        t.row(vec![
            name.to_string(),
            fmt_bytes(c.traffic()),
            c.flops.to_string(),
            c.launches.to_string(),
            g.interior_buffered_count_recursive().to_string(),
        ]);
    }
    t.print();
    println!(
        "  (rule 6 trades replicated flops for the removed interior buffer;\n   \
         rule 7 keeps flops flat but cannot remove the buffer — matching §3)"
    );
}
