//! Bench: the compiled-execution stack — naive tree-walking interpreter
//! vs the flat-tape engine (`ExecBackend::Compiled`, SIMD kernels +
//! work-stealing grid scheduler) vs the specialization backend
//! (`ExecBackend::Specialized`, recognized nests replaced by
//! pre-monomorphized fused kernel bodies) on every example program's
//! final fused kernel — the five canonical workloads plus
//! `decode_attention` — at shapes scaled up from the demo sizes, plus
//! per-kernel micro-bench rows (scalar vs SIMD) for the `tensor`
//! substrate.
//!
//! Both backends are timed on the same pre-blocked `ExecConfig`; the tape
//! is compiled once outside the timed loop (the amortization autotune
//! trials get: one skeleton, many bindings). Emits `BENCH_exec.json`
//! next to the textual table so the speedup trajectory is tracked from
//! this PR onward: `speedup_geomean` is the *within-commit* interp→
//! compiled ratio, `ew_speedup_geomean` the per-expression scalar-tape→
//! batched-VM ratio (the `exprs` rows), while the cross-PR compiled
//! trajectory (e.g. the "≥1.5× over the previous compiled baseline"
//! acceptance check) is the per-program `compiled_ms` fields diffed
//! across commits/CI artifacts. Set `BB_BENCH_SMOKE=1` for a
//! seconds-long CI smoke run at demo sizes.

use blockbuster::coordinator::workloads;
use blockbuster::exec::to_blocks;
use blockbuster::fusion::fuse;
use blockbuster::loopir::compile::{compile, compile_skeleton, specialize_skeleton};
use blockbuster::loopir::interp::{exec, ExecConfig};
use blockbuster::loopir::lower::lower;
use blockbuster::lower::lower_array;
use blockbuster::tensor::{simd, Rng};
use blockbuster::util::bench::{bench, fmt_stat, write_json_report, Table};
use blockbuster::util::json::Json;
use std::time::Duration;

fn main() {
    let smoke = std::env::var("BB_BENCH_SMOKE").is_ok();
    // Scale both the block-count grid and the full shapes: block sizes stay
    // at the demo 8×8, the grid gets `scale`× more iterations per dim.
    let scale = if smoke { 1 } else { 4 };
    let (min_iters, budget) = if smoke {
        (2, Duration::from_millis(150))
    } else {
        (5, Duration::from_millis(1200))
    };

    let mut t = Table::new(
        &format!(
            "Executor wall-clock, interpreter vs compiled tape vs specialized (grid scale {scale}x)"
        ),
        &["workload", "interp", "compiled", "specialized", "speedup", "spec_speedup"],
    );
    let mut rows = Vec::new();
    let mut log_speedups = 0.0f64;
    let mut spec_log_speedups = 0.0f64;
    let mut n_programs = 0usize;

    // the five canonical workloads plus the decode family's one-shot plan
    let bench_names = workloads::NAMES
        .iter()
        .copied()
        .chain(std::iter::once("decode_attention"));
    for name in bench_names {
        let (p, demo_cfg, params, _) = workloads::by_name(name, 42).unwrap();
        let mut sizes = demo_cfg.sizes.clone();
        for v in sizes.0.values_mut() {
            *v *= scale;
        }

        let g = lower_array(&p);
        let fused = fuse(g).snapshots.pop().unwrap();
        let ir = lower(&fused);

        // pre-block the scaled inputs once; both backends execute the same
        // config, so setup cost is outside every timed region
        let mut cfg = ExecConfig::new(sizes);
        cfg.params = params;
        let mut rng = Rng::new(7);
        let mut input_names: Vec<&String> = demo_cfg.full_shapes.keys().collect();
        input_names.sort(); // deterministic generation order
        for n in input_names {
            let (r, c) = demo_cfg.full_shapes[n];
            let m = rng.mat(r * scale, c * scale);
            let decl = &ir.bufs[ir.buf_by_name(n).expect("input buffer")];
            let rb = cfg.sizes.get(&decl.dims[0]);
            let cb = cfg.sizes.get(&decl.dims[1]);
            cfg.inputs.insert(n.clone(), to_blocks(&m, rb, cb));
        }

        let prog = compile(&ir, &cfg);
        // specialization happens once per skeleton (bind-time dispatch);
        // the timed region runs the same engine over the rewritten tape
        let skel = specialize_skeleton(&compile_skeleton(&ir, &cfg));
        let (fused_nests, total_nests) = skel
            .spec
            .as_ref()
            .map(|r| (r.fused_nests, r.total_nests))
            .unwrap_or((0, 0));
        let sprog = skel.bind(&cfg.sizes);
        let si = bench(min_iters, budget, || exec(&ir, &cfg));
        let sc = bench(min_iters, budget, || {
            blockbuster::exec::engine::exec_compiled(&prog, &cfg)
        });
        let ss = bench(min_iters, budget, || {
            blockbuster::exec::engine::exec_compiled(&sprog, &cfg)
        });
        let speedup = si.median_ns / sc.median_ns;
        let spec_speedup = sc.median_ns / ss.median_ns;
        log_speedups += speedup.ln();
        spec_log_speedups += spec_speedup.ln();
        n_programs += 1;
        t.row(vec![
            name.to_string(),
            fmt_stat(&si),
            fmt_stat(&sc),
            fmt_stat(&ss),
            format!("{speedup:.2}x"),
            format!("{spec_speedup:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("program", Json::Str(name.to_string())),
            ("interp_ms", Json::Num(si.median_ns / 1e6)),
            ("compiled_ms", Json::Num(sc.median_ns / 1e6)),
            ("specialized_ns", Json::Num(ss.median_ns)),
            // generic tape -> fused kernel bodies, same engine, same bind
            ("specialized_speedup", Json::Num(spec_speedup)),
            ("fused_nests", Json::Num(fused_nests as f64)),
            ("total_nests", Json::Num(total_nests as f64)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    let geomean = (log_speedups / n_programs.max(1) as f64).exp();
    let spec_geomean = (spec_log_speedups / n_programs.max(1) as f64).exp();
    t.print();
    println!("\ncompiled-backend speedup geomean: {geomean:.2}x");
    println!("specialize speedup geomean (compiled tape -> fused bodies): {spec_geomean:.2}x");

    // ---- per-kernel micro-bench: scalar vs SIMD ---------------------------
    let dim = if smoke { 32 } else { 128 };
    let avx = if simd::simd_active() {
        "available"
    } else {
        "unavailable"
    };
    let mut kt = Table::new(
        &format!("Kernel micro-bench at {dim}x{dim}, scalar vs SIMD (avx2 {avx})"),
        &["kernel", "scalar", "simd", "speedup"],
    );
    let mut krows = Vec::new();
    let mut rng = Rng::new(99);
    let a = rng.mat(dim, dim);
    let b = rng.mat(dim, dim);
    {
        let mut run_kernel = |kname: &str, f: &mut dyn FnMut() -> f32| {
            simd::set_enabled(false);
            let ss = bench(min_iters, budget / 4, &mut *f);
            simd::set_enabled(true);
            let sv = bench(min_iters, budget / 4, &mut *f);
            let speedup = ss.median_ns / sv.median_ns;
            kt.row(vec![
                kname.to_string(),
                fmt_stat(&ss),
                fmt_stat(&sv),
                format!("{speedup:.2}x"),
            ]);
            krows.push(Json::obj(vec![
                ("kernel", Json::Str(kname.to_string())),
                ("scalar_us", Json::Num(ss.median_ns / 1e3)),
                ("simd_us", Json::Num(sv.median_ns / 1e3)),
                ("speedup", Json::Num(speedup)),
            ]));
        };
        run_kernel("dot_bt", &mut || a.dot_bt(&b).at(0, 0));
        run_kernel("matmul", &mut || a.matmul(&b).at(0, 0));
        run_kernel("hadamard", &mut || a.hadamard(&b).at(0, 0));
        run_kernel("add", &mut || a.add(&b).at(0, 0));
        run_kernel("row_sum", &mut || a.row_sum()[0]);
        run_kernel("row_max", &mut || a.row_max()[0]);
    }
    simd::set_enabled(true);
    kt.print();

    // ---- per-expression micro-bench: scalar tape vs batched VM ------------
    // The elementwise chains that dominate the paper's fused mega-kernels,
    // evaluated over one dim×dim block: per-element `eval_with` (the old
    // `ComputeKind::Ew` path) vs one `ExprVm::run` (the new path). Both
    // run with SIMD enabled — this row isolates the batching win itself.
    use blockbuster::ir::expr::Expr;
    use blockbuster::ir::exprvm::{EwScratch, ExprVm};
    // same canned expressions the backend-parity suite certifies
    // (`Expr::softmax_tail` / `Expr::gelu_erf`), so the bench measures
    // exactly what the tests cover
    let exprs: Vec<(&str, Expr)> = vec![
        ("swish", Expr::swish(Expr::var(0))),
        ("softmax_tail", Expr::softmax_tail(Expr::var(0), Expr::var(1))),
        ("gelu_erf", Expr::gelu_erf(Expr::var(0))),
        ("relu", Expr::relu(Expr::var(0))),
    ];
    let mut et = Table::new(
        &format!("Elementwise expressions over a {dim}x{dim} block, scalar tape vs batched VM"),
        &["expr", "scalar", "vm", "speedup"],
    );
    let mut erows = Vec::new();
    let mut ew_log_speedups = 0.0f64;
    let x0: Vec<f32> = a.data.clone();
    let x1: Vec<f32> = b.data.clone();
    for (name, e) in &exprs {
        let ce = e.compile(&Default::default());
        let vm = ExprVm::from_compiled(&ce);
        let n = ce.arity;
        let args: Vec<&[f32]> = [&x0[..], &x1[..]][..n].to_vec();
        let mut scratch = EwScratch::new();
        let mut out = vec![0.0f32; x0.len()];
        let ss = bench(min_iters, budget / 4, || {
            let mut xs = [0.0f32; 2];
            for i in 0..out.len() {
                for (k, arg) in args.iter().enumerate() {
                    xs[k] = arg[i];
                }
                out[i] = ce.eval_with(&xs[..n], &mut scratch.stack);
            }
            out[0]
        });
        let sv = bench(min_iters, budget / 4, || {
            vm.run(&args, &mut out, &mut scratch);
            out[0]
        });
        let speedup = ss.median_ns / sv.median_ns;
        ew_log_speedups += speedup.ln();
        et.row(vec![
            name.to_string(),
            fmt_stat(&ss),
            fmt_stat(&sv),
            format!("{speedup:.2}x"),
        ]);
        erows.push(Json::obj(vec![
            ("expr", Json::Str(name.to_string())),
            ("scalar_us", Json::Num(ss.median_ns / 1e3)),
            ("vm_us", Json::Num(sv.median_ns / 1e3)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    let ew_geomean = (ew_log_speedups / exprs.len().max(1) as f64).exp();
    et.print();
    println!("\nexpression-VM speedup geomean: {ew_geomean:.2}x");

    let report = Json::obj(vec![
        ("bench", Json::Str("exec_backend_speedup".into())),
        ("grid_scale", Json::Num(scale as f64)),
        ("smoke", Json::Bool(smoke)),
        ("simd_active", Json::Bool(simd::simd_active())),
        (
            "threads",
            Json::Num(
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1) as f64,
            ),
        ),
        // geomean of interp/compiled ratios; compare `compiled_ms` per
        // program across commits (CI artifacts) for PR-over-PR compiled
        // trajectories — the acceptance comparison vs the PR 1 compiled
        // baseline is a cross-commit diff of those fields
        ("geomean_basis", Json::Str("interp_vs_compiled".into())),
        ("speedup_geomean", Json::Num(geomean)),
        // compiled-tape → specialized (fused kernel bodies) ratio over
        // the same per-program rows: the bind-time-dispatch win itself
        ("specialize_speedup_geomean", Json::Num(spec_geomean)),
        // scalar-tape → batched-VM ratio over the per-expression rows
        // below (both sides SIMD-on, so this isolates the batching win)
        ("ew_speedup_geomean", Json::Num(ew_geomean)),
        ("programs", Json::Arr(rows)),
        ("kernel_dim", Json::Num(dim as f64)),
        ("kernels", Json::Arr(krows)),
        ("exprs", Json::Arr(erows)),
    ]);
    write_json_report("BENCH_exec.json", &report).expect("writing BENCH_exec.json");
    println!("\nwrote BENCH_exec.json");
}
