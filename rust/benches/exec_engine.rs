//! Bench: the compiled-execution tentpole — naive tree-walking interpreter
//! vs the flat-tape engine (`ExecBackend::Compiled`) on every example
//! program's final fused kernel, at shapes scaled up from the demo sizes.
//!
//! Both backends are timed on the same pre-blocked `ExecConfig`; the tape
//! is compiled once outside the timed loop (the amortization autotune
//! trials get: one program, many executions). Emits `BENCH_exec.json`
//! next to the textual table so the interp→engine speedup trajectory is
//! tracked from this PR onward. Set `BB_BENCH_SMOKE=1` for a seconds-long
//! CI smoke run at demo sizes.

use blockbuster::coordinator::workloads;
use blockbuster::exec::to_blocks;
use blockbuster::fusion::fuse;
use blockbuster::loopir::compile::compile;
use blockbuster::loopir::interp::{exec, ExecConfig};
use blockbuster::loopir::lower::lower;
use blockbuster::lower::lower_array;
use blockbuster::tensor::Rng;
use blockbuster::util::bench::{bench, fmt_stat, write_json_report, Table};
use blockbuster::util::json::Json;
use std::time::Duration;

fn main() {
    let smoke = std::env::var("BB_BENCH_SMOKE").is_ok();
    // Scale both the block-count grid and the full shapes: block sizes stay
    // at the demo 8×8, the grid gets `scale`× more iterations per dim.
    let scale = if smoke { 1 } else { 4 };
    let (min_iters, budget) = if smoke {
        (2, Duration::from_millis(150))
    } else {
        (5, Duration::from_millis(1200))
    };

    let mut t = Table::new(
        &format!("Executor wall-clock, interpreter vs compiled tape (grid scale {scale}x)"),
        &["workload", "interp", "compiled", "speedup"],
    );
    let mut rows = Vec::new();

    for name in workloads::NAMES {
        let (p, demo_cfg, params, _) = workloads::by_name(name, 42).unwrap();
        let mut sizes = demo_cfg.sizes.clone();
        for v in sizes.0.values_mut() {
            *v *= scale;
        }

        let g = lower_array(&p);
        let fused = fuse(g).snapshots.pop().unwrap();
        let ir = lower(&fused);

        // pre-block the scaled inputs once; both backends execute the same
        // config, so setup cost is outside every timed region
        let mut cfg = ExecConfig::new(sizes);
        cfg.params = params;
        let mut rng = Rng::new(7);
        let mut input_names: Vec<&String> = demo_cfg.full_shapes.keys().collect();
        input_names.sort(); // deterministic generation order
        for n in input_names {
            let (r, c) = demo_cfg.full_shapes[n];
            let m = rng.mat(r * scale, c * scale);
            let decl = &ir.bufs[ir.buf_by_name(n).expect("input buffer")];
            let rb = cfg.sizes.get(&decl.dims[0]);
            let cb = cfg.sizes.get(&decl.dims[1]);
            cfg.inputs.insert(n.clone(), to_blocks(&m, rb, cb));
        }

        let prog = compile(&ir, &cfg);
        let si = bench(min_iters, budget, || exec(&ir, &cfg));
        let sc = bench(min_iters, budget, || {
            blockbuster::exec::engine::exec_compiled(&prog, &cfg)
        });
        let speedup = si.median_ns / sc.median_ns;
        t.row(vec![
            name.to_string(),
            fmt_stat(&si),
            fmt_stat(&sc),
            format!("{speedup:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("program", Json::Str(name.to_string())),
            ("interp_ms", Json::Num(si.median_ns / 1e6)),
            ("compiled_ms", Json::Num(sc.median_ns / 1e6)),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    t.print();
    let report = Json::obj(vec![
        ("bench", Json::Str("exec_backend_speedup".into())),
        ("grid_scale", Json::Num(scale as f64)),
        ("smoke", Json::Bool(smoke)),
        ("programs", Json::Arr(rows)),
    ]);
    write_json_report("BENCH_exec.json", &report).expect("writing BENCH_exec.json");
    println!("\nwrote BENCH_exec.json");
}
