//! Bench: simulator wall-clock, naive vs final fused program, for every
//! demo workload. (The simulator's time tracks instruction count, so this
//! is a proxy for the work the abstract machine performs; the *traffic*
//! table is the paper's own metric.)

use blockbuster::coordinator::workloads;
use blockbuster::exec::{run_lowered, Workload};
use blockbuster::fusion::fuse;
use blockbuster::loopir::lower::lower;
use blockbuster::lower::lower_array;
use blockbuster::util::bench::{fmt_stat, quick, Table};

fn main() {
    let mut t = Table::new(
        "Simulator execution time (median ± σ)",
        &["workload", "naive", "fused", "speedup"],
    );
    for name in workloads::NAMES {
        let (p, cfg, params, inputs) = workloads::by_name(name, 42).unwrap();
        let g = lower_array(&p);
        let fused = fuse(g.clone()).snapshots.pop().unwrap();
        let wl = Workload {
            sizes: cfg.sizes.clone(),
            params,
            inputs,
            local_capacity: None,
            threads: None,
        };
        let ir_naive = lower(&g);
        let ir_fused = lower(&fused);
        let sn = quick(|| run_lowered(&ir_naive, &wl));
        let sf = quick(|| run_lowered(&ir_fused, &wl));
        t.row(vec![
            name.to_string(),
            fmt_stat(&sn),
            fmt_stat(&sf),
            format!("{:.2}x", sn.median_ns / sf.median_ns),
        ]);
    }
    t.print();
}
