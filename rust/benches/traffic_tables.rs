//! Bench: the headline metric — global-memory traffic and kernel launches,
//! naive (fully unfused Table-2 program) vs every fusion snapshot, measured
//! exactly by the two-tier memory simulator. Regenerates the quantitative
//! content behind each example's epilogue ("the only remaining buffered
//! edges are those incident with inputs/outputs").

use blockbuster::coordinator::workloads;
use blockbuster::exec::{run, Workload};
use blockbuster::fusion::fuse;
use blockbuster::lower::lower_array;
use blockbuster::util::bench::{fmt_bytes, Table};

fn main() {
    for name in workloads::NAMES {
        let (p, cfg, params, inputs) = workloads::by_name(name, 42).unwrap();
        let g = lower_array(&p);
        let res = fuse(g.clone());
        let wl = Workload {
            sizes: cfg.sizes.clone(),
            params,
            inputs,
            local_capacity: None,
            threads: None,
        };
        let naive = run(&g, &wl);
        let mut t = Table::new(
            &format!("{name}: measured two-tier traffic"),
            &[
                "variant",
                "loads",
                "stores",
                "traffic",
                "vs naive",
                "launches",
                "flops",
                "peak local",
            ],
        );
        let mut row = |label: String, mem: &blockbuster::loopir::interp::MemSim| {
            t.row(vec![
                label,
                fmt_bytes(mem.loaded_bytes),
                fmt_bytes(mem.stored_bytes),
                fmt_bytes(mem.total_traffic()),
                format!(
                    "{:.2}x",
                    naive.mem.total_traffic() as f64 / mem.total_traffic() as f64
                ),
                mem.kernel_launches.to_string(),
                mem.flops.to_string(),
                fmt_bytes(mem.peak_local_bytes),
            ]);
        };
        row("naive (unfused)".into(), &naive.mem);
        for (i, snap) in res.snapshots.iter().enumerate() {
            let r = run(snap, &wl);
            let label = if i + 1 == res.snapshots.len() {
                format!("snapshot {i} (final)")
            } else {
                format!("snapshot {i}")
            };
            row(label, &r.mem);
        }
        t.print();
    }
}
