//! Bench: end-to-end PJRT latency of every AOT artifact (naive JAX model vs
//! the fused Pallas kernel) plus the Rust plan-executor wall-clock for the
//! decoder workload. Skips gracefully when artifacts are missing.

use blockbuster::coordinator::{compile, execute_plan, workloads};
use blockbuster::runtime::Runtime;
use blockbuster::tensor::{Mat, Rng};
use blockbuster::util::bench::{bench, fmt_stat, Table};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP runtime_e2e: run `make artifacts` first");
        return Ok(());
    }
    let mut rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    let pairs = [
        "matmul_relu",
        "attention",
        "layernorm_matmul",
        "rmsnorm_ffn_swiglu",
        "decoder_block",
    ];
    let mut t = Table::new(
        "XLA/PJRT steady-state latency: naive JAX model vs fused Pallas kernel",
        &["model", "naive", "pallas-fused", "ratio"],
    );
    for base in pairs {
        let naive_name = format!("{base}_naive");
        let fused_name = format!("{base}_fused");
        let info = rt.manifest.model(&naive_name)?.clone();
        let mut rng = Rng::new(7);
        let mats: Vec<Mat> = info
            .inputs
            .iter()
            .map(|(_, s)| rng.mat(s[0], s[1]))
            .collect();
        let refs: Vec<&Mat> = mats.iter().collect();
        rt.prepare(&naive_name)?;
        rt.prepare(&fused_name)?;
        // correctness gate before timing
        let a = rt.execute(&naive_name, &refs)?;
        let b = rt.execute(&fused_name, &refs)?;
        let d = a[0].max_abs_diff(&b[0]);
        assert!(d < 5e-3, "{base}: naive vs fused differ by {d}");
        let sn = bench(10, Duration::from_millis(900), || {
            rt.execute(&naive_name, &refs).unwrap()
        });
        let sf = bench(10, Duration::from_millis(900), || {
            rt.execute(&fused_name, &refs).unwrap()
        });
        t.row(vec![
            base.to_string(),
            fmt_stat(&sn),
            fmt_stat(&sf),
            format!("{:.2}x", sn.median_ns / sf.median_ns),
        ]);
    }
    t.print();
    println!(
        "  (CPU PJRT: the Pallas kernels run interpret-mode HLO — XLA already\n   \
         fuses the naive models aggressively on CPU, so parity is expected;\n   \
         the simulator traffic tables carry the paper's actual claim)"
    );

    // Rust-side plan executor on the decoder workload
    let (p, cfg, params, inputs) = workloads::decoder_demo(42);
    let compiled = compile(&p, cfg.clone());
    let s = bench(5, Duration::from_millis(1200), || {
        execute_plan(&compiled.plan, &cfg.sizes, &params, &inputs)
    });
    println!("\nRust plan-executor, decoder block: {}", fmt_stat(&s));
    Ok(())
}
