//! Bench: the compile-once serving layer (`serve::ModelServer`) —
//! closed-loop throughput and end-to-end latency at dynamic batch sizes
//! 1/4/16 on one workload, coalesced (stacked-launch) vs fanned
//! execution of the same batched stream, a ragged mixed-length stream
//! (shape-bucketed + padded stacking vs own-length fan-out), a mixed
//! 3-workload round-robin
//! stream, the compile-amortization ratio (how many served requests
//! pay back one `coordinator::compile` + plan prepare), *open-loop*
//! arrival curves through the daemon (p50/p95/p99 + shed counts at
//! 0.5x/1x/2x of measured capacity against a bounded queue), and a
//! seeded-fault row (containment + counter reconciliation under
//! injected batch panics). Emits `BENCH_serve.json` next to the textual
//! tables; set `BB_BENCH_SMOKE=1` for the seconds-long CI run.
//!
//! Latency here is enqueue→response (queue wait + batched launch), so a
//! full burst's tail requests see queueing delay — the realistic
//! closed-loop number, not the bare launch time. The open-loop rows
//! pace arrivals independently of completions, which is what actually
//! separates an overloaded server from a busy one.

use blockbuster::coordinator::plan_stack_info;
use blockbuster::exec::ExecBackend;
use blockbuster::serve::daemon::{Daemon, Ticket};
use blockbuster::serve::net::client::{synthetic_request, ClientConfig, NetClient};
use blockbuster::serve::net::proto::Frame;
use blockbuster::serve::net::{NetConfig, NetServer};
use blockbuster::serve::{BucketLadder, ModelServer, Request, Response, ServerConfig, Verdict};
use blockbuster::util::bench::{percentile, write_json_report, Table};
use blockbuster::util::fault;
use blockbuster::util::json::Json;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

fn server_with(max_batch: usize, coalesce: bool, mix: &[&str]) -> ModelServer {
    let mut s = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: None,
        max_batch,
        max_wait: Duration::from_secs(3600),
        coalesce,
        ..ServerConfig::default()
    });
    for name in mix {
        s.register(name).unwrap();
    }
    s
}

fn main() {
    let smoke = std::env::var("BB_BENCH_SMOKE").is_ok();
    let program = "rmsnorm_ffn_swiglu";
    let n_requests = if smoke { 24 } else { 192 };

    // ---- compile-once cost: register (compile + prepare) one workload
    let t0 = Instant::now();
    drop(server_with(8, false, &[program]));
    let compile_ns = t0.elapsed().as_nanos() as f64;

    // ---- single-workload throughput/latency at batch sizes 1/4/16 ----
    let mut t = Table::new(
        &format!("Serving {program}, {n_requests} requests per row"),
        &["max_batch", "throughput", "mean lat", "p95 lat"],
    );
    let mut rows = Vec::new();
    let mut steady_ns_per_req = f64::NAN;
    for batch in [1usize, 4, 16] {
        let mut server = server_with(batch, false, &[program]);
        // warmup: one full batch through the whole path
        for i in 0..batch as u64 {
            server.submit_synthetic(program, i).unwrap();
        }
        server.drain();

        let t1 = Instant::now();
        for i in 0..n_requests as u64 {
            server.submit_synthetic(program, 10_000 + i).unwrap();
        }
        let responses = server.drain();
        let wall = t1.elapsed();
        assert_eq!(responses.len(), n_requests);

        let lat: Vec<u128> = responses.iter().map(|r| r.queue_ns + r.exec_ns).collect();
        let mean_us = lat.iter().sum::<u128>() as f64 / lat.len() as f64 / 1e3;
        let p95_us = percentile(&lat, 95.0) as f64 / 1e3;
        let rps = n_requests as f64 / wall.as_secs_f64();
        let ns_per_req = wall.as_nanos() as f64 / n_requests as f64;
        if batch == 16 {
            steady_ns_per_req = ns_per_req;
        }
        t.row(vec![
            batch.to_string(),
            format!("{rps:.0} req/s"),
            format!("{mean_us:.1}µs"),
            format!("{p95_us:.1}µs"),
        ]);
        rows.push(Json::obj(vec![
            ("batch", Json::Num(batch as f64)),
            ("throughput_rps", Json::Num(rps)),
            ("mean_latency_us", Json::Num(mean_us)),
            ("p95_latency_us", Json::Num(p95_us)),
        ]));
    }
    t.print();

    // ---- coalesced (stacked launch) vs fanned, same batched stream ----
    // Synthetic requests share weights bit-for-bit, so with coalescing
    // on every full batch rides ONE stacked tape launch; off, each
    // request is its own plan execution fanned across the pool.
    let mut ct = Table::new(
        &format!("Coalescing {program}, max_batch 16, {n_requests} requests"),
        &["mode", "throughput", "kernel launches", "stacked batches"],
    );
    let mut coalesce_rows = Vec::new();
    let mut rps_by_mode = [f64::NAN; 2];
    for (mi, coalesce) in [false, true].into_iter().enumerate() {
        let mut server = server_with(16, coalesce, &[program]);
        for i in 0..16u64 {
            server.submit_synthetic(program, i).unwrap(); // warmup
        }
        server.drain();
        // counter baseline after warmup, so the reported launch ledger
        // covers exactly the timed stream
        let (warm_launches, warm_stacked, warm_coalesced) = {
            let st = &server.stats().per_program[program];
            (st.launches, st.stacked_batches, st.coalesced)
        };
        let t1 = Instant::now();
        for i in 0..n_requests as u64 {
            server.submit_synthetic(program, 30_000 + i).unwrap();
        }
        let responses = server.drain();
        let wall = t1.elapsed();
        assert_eq!(responses.len(), n_requests);
        let st = &server.stats().per_program[program];
        let launches = st.launches - warm_launches;
        let stacked_batches = st.stacked_batches - warm_stacked;
        if coalesce {
            assert!(st.coalesced - warm_coalesced > 0, "coalescing must engage on {program}");
        }
        let rps = n_requests as f64 / wall.as_secs_f64();
        rps_by_mode[mi] = rps;
        ct.row(vec![
            if coalesce { "coalesced" } else { "fanned" }.to_string(),
            format!("{rps:.0} req/s"),
            launches.to_string(),
            stacked_batches.to_string(),
        ]);
        coalesce_rows.push(Json::obj(vec![
            ("coalesce", Json::Bool(coalesce)),
            ("throughput_rps", Json::Num(rps)),
            ("kernel_launches", Json::Num(launches as f64)),
            ("stacked_batches", Json::Num(stacked_batches as f64)),
        ]));
    }
    ct.print();
    let coalesce_speedup = rps_by_mode[1] / rps_by_mode[0];
    println!("coalesce_speedup: {coalesce_speedup:.2}x (stacked vs fanned throughput)");

    // ---- ragged stream: shape-bucketed coalescing vs fan-out ----------
    // Requests differ along the stackable grid dim (trips cycle 1..=R).
    // Coalesced mode buckets them under the max ladder and pads each to
    // the bucket edge (pad waste charged separately as `padded_flops`);
    // fanned mode executes each request alone at its own length.
    let mut rt = Table::new(
        &format!("Ragged {program}, max_batch 16, {n_requests} requests, trips 1..=R"),
        &["mode", "throughput", "stacked batches", "pad flops"],
    );
    let mut ragged_rows = Vec::new();
    let mut ragged_rps_by_mode = [f64::NAN; 2];
    for (mi, coalesce) in [false, true].into_iter().enumerate() {
        let mut server = ModelServer::new(ServerConfig {
            backend: ExecBackend::Compiled,
            threads: None,
            max_batch: 16,
            max_wait: Duration::from_secs(3600),
            coalesce,
            buckets: BucketLadder::Max,
            pad: coalesce,
            ..ServerConfig::default()
        });
        server.register(program).unwrap();
        let trip = plan_stack_info(&server.live_plan(program).unwrap())
            .expect("bench workload must stack")
            .trip;
        for i in 0..16u64 {
            server.submit_synthetic_ragged(program, i, 1 + (i as usize % trip)).unwrap();
        }
        server.drain();
        let (warm_stacked, warm_pad) = {
            let st = &server.stats().per_program[program];
            (st.stacked_batches, st.padded_flops)
        };
        let t1 = Instant::now();
        for i in 0..n_requests as u64 {
            let r = 1 + (i as usize % trip);
            server.submit_synthetic_ragged(program, 70_000 + i, r).unwrap();
        }
        let responses = server.drain();
        let wall = t1.elapsed();
        assert_eq!(responses.len(), n_requests);
        let st = &server.stats().per_program[program];
        let stacked_batches = st.stacked_batches - warm_stacked;
        let pad_flops = st.padded_flops - warm_pad;
        if coalesce {
            assert!(stacked_batches > 0, "ragged coalescing must engage on {program}");
        }
        let rps = n_requests as f64 / wall.as_secs_f64();
        ragged_rps_by_mode[mi] = rps;
        rt.row(vec![
            if coalesce { "coalesced" } else { "fanned" }.to_string(),
            format!("{rps:.0} req/s"),
            stacked_batches.to_string(),
            pad_flops.to_string(),
        ]);
        ragged_rows.push(Json::obj(vec![
            ("coalesce", Json::Bool(coalesce)),
            ("throughput_rps", Json::Num(rps)),
            ("stacked_batches", Json::Num(stacked_batches as f64)),
            ("padded_flops", Json::Num(pad_flops as f64)),
        ]));
    }
    rt.print();
    let ragged_speedup = ragged_rps_by_mode[1] / ragged_rps_by_mode[0];
    println!("ragged_speedup: {ragged_speedup:.2}x (bucketed stacked vs fanned, mixed lengths)");

    // ---- KV-cache decode: stacked same-length steps vs fan-out --------
    // Sessions share the synthetic per-step KV stream, so at every cache
    // length the open sessions hold bit-identical caches — with
    // coalescing on, each cache-length bucket flushes as ONE stacked
    // flash-decode launch; off, every step executes alone.
    let dname = "decode_attention";
    let d_sessions = if smoke { 4 } else { 8 };
    let d_waves = if smoke { 2 } else { 6 };
    let mut dt = Table::new(
        &format!("Decode {dname}, {d_sessions} sessions to full cache, {d_waves} wave(s)"),
        &["mode", "throughput", "steps", "stacked batches", "KV bytes"],
    );
    let mut decode_rows = Vec::new();
    let mut decode_sps_by_mode = [f64::NAN; 2];
    let mut decode_cap = 0usize;
    for (mi, coalesce) in [false, true].into_iter().enumerate() {
        let mut server = server_with(16, coalesce, &[dname]);
        // Warmup doubles as cap discovery: step one throwaway session
        // until its cache is full.
        let cap = {
            let sid = server.open_session(dname).unwrap();
            let mut n = 0usize;
            while server.submit_synthetic_decode(sid, 1).is_ok() {
                n += 1;
            }
            server.drain();
            server.close_session(sid).unwrap();
            n
        };
        assert!(cap > 0, "decode workload must register a growth cap");
        decode_cap = cap;
        let (warm_stacked, warm_bytes) = {
            let st = &server.stats().per_program[dname];
            (st.stacked_batches, st.state_appended_bytes)
        };
        let steps_total = d_waves * d_sessions * cap;
        let t1 = Instant::now();
        let mut served = 0usize;
        for wave in 0..d_waves as u64 {
            let sids: Vec<u64> = (0..d_sessions)
                .map(|_| server.open_session(dname).unwrap())
                .collect();
            // Round-major: step t for EVERY session before any t+1, so
            // same-length steps share a bucket flush.
            for _ in 0..cap {
                for (s, &sid) in sids.iter().enumerate() {
                    server.submit_synthetic_decode(sid, wave * 100 + s as u64).unwrap();
                }
            }
            served += server.drain().iter().filter(|r| r.is_ok()).count();
            for sid in sids {
                server.close_session(sid).unwrap();
            }
        }
        let wall = t1.elapsed();
        assert_eq!(served, steps_total, "every decode step must serve");
        let st = &server.stats().per_program[dname];
        let stacked = st.stacked_batches - warm_stacked;
        let kv_bytes = st.state_appended_bytes - warm_bytes;
        if coalesce {
            assert!(stacked > 0, "decode coalescing must engage");
        }
        let sps = steps_total as f64 / wall.as_secs_f64();
        decode_sps_by_mode[mi] = sps;
        dt.row(vec![
            if coalesce { "coalesced" } else { "fanned" }.to_string(),
            format!("{sps:.0} steps/s"),
            steps_total.to_string(),
            stacked.to_string(),
            kv_bytes.to_string(),
        ]);
        decode_rows.push(Json::obj(vec![
            ("coalesce", Json::Bool(coalesce)),
            ("throughput_sps", Json::Num(sps)),
            ("steps", Json::Num(steps_total as f64)),
            ("stacked_batches", Json::Num(stacked as f64)),
            ("kv_appended_bytes", Json::Num(kv_bytes as f64)),
        ]));
    }
    dt.print();
    let decode_speedup = decode_sps_by_mode[1] / decode_sps_by_mode[0];
    println!("decode_speedup: {decode_speedup:.2}x (stacked decode vs per-step fan-out)");

    // ---- mixed prefill + decode on one server -------------------------
    // Stateless prefill requests and stateful decode steps share the
    // server, the bucket queues, and the flush sweep: decode buckets
    // stack by cache length while prefill batches stack along the
    // row-block grid.
    let pname = "attention";
    let mut server = server_with(16, true, &[pname, dname]);
    let sid0 = server.open_session(dname).unwrap();
    while server.submit_synthetic_decode(sid0, 1).is_ok() {}
    server.submit_synthetic(pname, 0).unwrap();
    server.drain();
    server.close_session(sid0).unwrap();
    let mixed_t0 = Instant::now();
    let mut md_prefill = 0usize;
    let mut md_steps = 0usize;
    for wave in 0..d_waves as u64 {
        let sids: Vec<u64> = (0..d_sessions)
            .map(|_| server.open_session(dname).unwrap())
            .collect();
        for step in 0..decode_cap as u64 {
            for (s, &sid) in sids.iter().enumerate() {
                server.submit_synthetic_decode(sid, 7_000 + wave * 100 + s as u64).unwrap();
            }
            for k in 0..d_sessions as u64 {
                server.submit_synthetic(pname, 80_000 + (wave * 100 + step) * 16 + k).unwrap();
            }
        }
        for r in server.drain() {
            assert!(r.is_ok(), "mixed prefill/decode row must serve everything");
            if r.workload == dname {
                md_steps += 1;
            } else {
                md_prefill += 1;
            }
        }
        for sid in sids {
            server.close_session(sid).unwrap();
        }
    }
    let md_wall = mixed_t0.elapsed();
    let md_total = md_prefill + md_steps;
    for (name, st) in &server.stats().per_program {
        assert_eq!(st.accounted(), st.submitted, "{name}: mixed-decode ledger must reconcile");
    }
    let md_rps = md_total as f64 / md_wall.as_secs_f64();
    let md_stacked: u64 = server.stats().per_program.values().map(|s| s.stacked_batches).sum();
    println!(
        "mixed prefill+decode: {md_rps:.0} req/s over {md_prefill} prefill + {md_steps} decode \
         steps ({md_stacked} stacked launches incl. warmup)"
    );
    let mixed_decode_obj = Json::obj(vec![
        ("prefill_program", Json::Str(pname.into())),
        ("prefill_served", Json::Num(md_prefill as f64)),
        ("decode_steps", Json::Num(md_steps as f64)),
        ("throughput_rps", Json::Num(md_rps)),
        ("stacked_batches", Json::Num(md_stacked as f64)),
    ]);

    // ---- mixed 3-workload round-robin stream --------------------------
    let mix = ["quickstart", "attention", "rmsnorm_ffn_swiglu"];
    let mut server = server_with(8, false, &mix);
    for (i, name) in mix.iter().enumerate() {
        server.submit_synthetic(name, i as u64).unwrap(); // warmup
    }
    server.drain();
    let t2 = Instant::now();
    for (i, name) in mix.iter().cycle().take(n_requests).enumerate() {
        server.submit_synthetic(name, 20_000 + i as u64).unwrap();
    }
    let responses = server.drain();
    let mixed_wall = t2.elapsed();
    assert_eq!(responses.len(), n_requests);
    let mixed_rps = n_requests as f64 / mixed_wall.as_secs_f64();
    let compiles: u64 = server.stats().per_program.values().map(|s| s.compiles).sum();
    println!(
        "\nmixed {} stream: {mixed_rps:.0} req/s over {n_requests} requests, {compiles} compiles",
        mix.join("+")
    );

    // ---- compile amortization ----------------------------------------
    let amortize = compile_ns / steady_ns_per_req;
    println!(
        "compile+prepare {:.2}ms ≈ {amortize:.0} steady-state requests (batch 16)",
        compile_ns / 1e6
    );

    // ---- open-loop arrival curves through the daemon ------------------
    // Arrivals are paced independently of completions (open loop): at
    // 0.5x measured capacity the queue stays short; past 1x the bounded
    // queue sheds the overload with typed rejections and p99 saturates
    // near queue_cap * service time instead of growing without bound.
    let capacity_rps = 1e9 / steady_ns_per_req;
    let open_n = if smoke { 32 } else { 128 };
    let mut ot = Table::new(
        &format!("Open-loop {program} via daemon, queue_cap 32, {open_n} arrivals per row"),
        &["offered", "served", "shed", "p50 lat", "p95 lat", "p99 lat"],
    );
    let mut open_loop_rows = Vec::new();
    for factor in [0.5f64, 1.0, 2.0] {
        let offered_rps = capacity_rps * factor;
        let mut s = ModelServer::new(ServerConfig {
            backend: ExecBackend::Compiled,
            threads: None,
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            coalesce: false,
            queue_cap: Some(32),
            ..ServerConfig::default()
        });
        s.register(program).unwrap();
        // pre-generate the stream (inputs need the server's shape specs)
        let reqs: Vec<Request> = (0..open_n as u64)
            .map(|i| Request::new(program, s.synthetic_inputs(program, 40_000 + i).unwrap()))
            .collect();
        let daemon = Daemon::start(s, None);
        let client = daemon.client();
        let t1 = Instant::now();
        let mut tickets: Vec<Ticket> = Vec::with_capacity(open_n);
        for (i, req) in reqs.into_iter().enumerate() {
            let due = Duration::from_secs_f64(i as f64 / offered_rps);
            let now = t1.elapsed();
            if due > now {
                std::thread::sleep(due - now);
            }
            tickets.push(client.submit(req));
        }
        let responses: Vec<Response> = tickets.into_iter().map(|t| t.wait()).collect();
        let server = daemon.shutdown();
        assert_eq!(responses.len(), open_n);
        let st = &server.stats().per_program[program];
        assert_eq!(st.accounted(), st.submitted, "open-loop counters must reconcile at {factor}x");
        let lat: Vec<u128> = responses
            .iter()
            .filter(|r| r.is_ok())
            .map(|r| r.queue_ns + r.exec_ns)
            .collect();
        let shed = st.rejected();
        let (p50, p95, p99) = (
            percentile(&lat, 50.0) as f64 / 1e3,
            percentile(&lat, 95.0) as f64 / 1e3,
            percentile(&lat, 99.0) as f64 / 1e3,
        );
        ot.row(vec![
            format!("{factor:.1}x cap"),
            st.served.to_string(),
            shed.to_string(),
            format!("{p50:.1}µs"),
            format!("{p95:.1}µs"),
            format!("{p99:.1}µs"),
        ]);
        open_loop_rows.push(Json::obj(vec![
            ("offered_factor", Json::Num(factor)),
            ("offered_rps", Json::Num(offered_rps)),
            ("served", Json::Num(st.served as f64)),
            ("shed", Json::Num(shed as f64)),
            ("p50_latency_us", Json::Num(p50)),
            ("p95_latency_us", Json::Num(p95)),
            ("p99_latency_us", Json::Num(p99)),
        ]));
    }
    ot.print();

    // ---- seeded faults: containment + accounting under panics ---------
    let fault_n = if smoke { 24 } else { 96 };
    fault::set(0.2, 0xb10c_fa17);
    let mut s = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: None,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        coalesce: false,
        ..ServerConfig::default()
    });
    s.register(program).unwrap();
    let reqs: Vec<Request> = (0..fault_n as u64)
        .map(|i| Request::new(program, s.synthetic_inputs(program, 50_000 + i).unwrap()))
        .collect();
    let daemon = Daemon::start(s, None);
    let client = daemon.client();
    let tickets: Vec<Ticket> = reqs.into_iter().map(|r| client.submit(r)).collect();
    let responses: Vec<Response> = tickets.into_iter().map(|t| t.wait()).collect();
    let server = daemon.shutdown();
    fault::off();
    assert_eq!(responses.len(), fault_n, "every submission answered under faults");
    let st = &server.stats().per_program[program];
    assert_eq!(st.accounted(), st.submitted, "fault-row counters must reconcile");
    println!(
        "\nfaults @ 20%: {} submitted = {} served + {} failed \
         ({} contained panic(s)); daemon never aborted",
        st.submitted, st.served, st.failed, st.panics
    );
    let fault_obj = Json::obj(vec![
        ("rate", Json::Num(0.2)),
        ("submitted", Json::Num(st.submitted as f64)),
        ("served", Json::Num(st.served as f64)),
        ("failed", Json::Num(st.failed as f64)),
        ("contained_panics", Json::Num(st.panics as f64)),
    ]);

    // ---- loopback TCP ingress: what the wire protocol costs -----------
    // The same closed-loop stream, but over a real socket: preamble
    // handshake, checksummed frame encode/decode both ways, and the
    // per-connection reader/writer pair in front of the daemon.
    let net_n = if smoke { 24 } else { 96 };
    let mut s = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: None,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        coalesce: false,
        ..ServerConfig::default()
    });
    s.register(program).unwrap();
    let daemon = Daemon::start(s, None);
    let net = NetServer::start("127.0.0.1:0", daemon.client(), NetConfig::default())
        .expect("loopback listener");
    let mut cli = NetClient::connect(&net.local_addr().to_string(), ClientConfig::default())
        .expect("loopback connect");
    // one warmup round trip so connect/compile costs stay out of the row
    let warm = cli.call_synthetic(program, u64::MAX, 59_999).expect("loopback warmup");
    assert_eq!(warm.verdict, Verdict::Ok, "warmup must serve");
    let window = 16usize;
    let mut sent = 0usize;
    let mut got = 0usize;
    let mut in_flight: VecDeque<Instant> = VecDeque::new();
    let mut net_lat: Vec<u128> = Vec::with_capacity(net_n);
    let t_net = Instant::now();
    while got < net_n {
        while sent < net_n && in_flight.len() < window {
            let req = synthetic_request(program, sent as u64, 60_000 + sent as u64).unwrap();
            cli.send(&req).expect("loopback send");
            in_flight.push_back(Instant::now());
            sent += 1;
        }
        match cli.recv().expect("loopback recv") {
            Frame::Response(r) => {
                assert_eq!(r.verdict, Verdict::Ok, "loopback row must serve everything");
                let sent_at = in_flight.pop_front().expect("response without a request");
                net_lat.push(sent_at.elapsed().as_nanos());
                got += 1;
            }
            other => panic!("unexpected frame in loopback row: {other:?}"),
        }
    }
    let net_wall = t_net.elapsed();
    drop(cli);
    net.begin_shutdown();
    let server = daemon.shutdown();
    let net_stats = net.shutdown();
    assert!(net_stats.reconciles(), "loopback ledger must reconcile: {net_stats:?}");
    let st = &server.stats().per_program[program];
    assert_eq!(st.accounted(), st.submitted, "loopback row counters must reconcile");
    let net_rps = net_n as f64 / net_wall.as_secs_f64();
    let np50 = percentile(&net_lat, 50.0) as f64 / 1e3;
    let np95 = percentile(&net_lat, 95.0) as f64 / 1e3;
    let np99 = percentile(&net_lat, 99.0) as f64 / 1e3;
    println!(
        "\nloopback socket: {net_rps:.0} req/s over {net_n} pipelined requests \
         (p50 {np50:.1}µs, p95 {np95:.1}µs, p99 {np99:.1}µs end to end over TCP)"
    );
    let loopback_obj = Json::obj(vec![
        ("requests", Json::Num(net_n as f64)),
        ("pipeline_window", Json::Num(window as f64)),
        ("throughput_rps", Json::Num(net_rps)),
        ("p50_latency_us", Json::Num(np50)),
        ("p95_latency_us", Json::Num(np95)),
        ("p99_latency_us", Json::Num(np99)),
        ("delivered", Json::Num(net_stats.delivered as f64)),
    ]);

    let report = Json::obj(vec![
        ("bench", Json::Str("serve".into())),
        ("smoke", Json::Bool(smoke)),
        ("program", Json::Str(program.into())),
        ("requests", Json::Num(n_requests as f64)),
        ("compile_ms", Json::Num(compile_ns / 1e6)),
        // requests whose steady-state serving time equals one compile —
        // the compile-once amortization horizon
        ("amortize_requests", Json::Num(amortize)),
        ("batch_rows", Json::Arr(rows)),
        // stacked-launch coalescing vs per-request fan-out on the same
        // batched stream (throughput ratio; >1 means coalescing wins)
        ("coalesce_speedup", Json::Num(coalesce_speedup)),
        ("coalesce_rows", Json::Arr(coalesce_rows)),
        // mixed-length (ragged) stream: shape-bucketed stacked launches
        // with pad-to-bucket vs per-request fan-out at own length
        ("ragged_speedup", Json::Num(ragged_speedup)),
        ("ragged_rows", Json::Arr(ragged_rows)),
        // KV-cache decode sessions: same-cache-length steps stacked per
        // bucket (speedup >1 means stacked decode beats per-step fan-out)
        ("decode_speedup", Json::Num(decode_speedup)),
        ("decode_rows", Json::Arr(decode_rows)),
        // stateless prefill + stateful decode steps sharing one server's
        // bucket queues and flush sweep
        ("mixed_decode", mixed_decode_obj),
        (
            "mixed",
            Json::obj(vec![
                (
                    "programs",
                    Json::Arr(mix.iter().map(|s| Json::Str(s.to_string())).collect()),
                ),
                ("requests", Json::Num(n_requests as f64)),
                ("throughput_rps", Json::Num(mixed_rps)),
                ("compiles", Json::Num(compiles as f64)),
            ]),
        ),
        // paced (open-loop) arrivals vs a bounded queue: offered load,
        // shed counts, and the latency tail per offered/capacity ratio
        ("open_loop_rows", Json::Arr(open_loop_rows)),
        // seeded 20% batch-panic injection: the daemon keeps serving,
        // failures are typed responses, and the ledger still reconciles
        ("fault", fault_obj),
        // framed requests over a real loopback socket: end-to-end wire
        // latency and throughput through the TCP ingress
        ("loopback", loopback_obj),
    ]);
    write_json_report("BENCH_serve.json", &report).expect("writing BENCH_serve.json");
    println!("\nwrote BENCH_serve.json");
}
