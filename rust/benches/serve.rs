//! Bench: the compile-once serving layer (`serve::ModelServer`) —
//! closed-loop throughput and end-to-end latency at dynamic batch sizes
//! 1/4/16 on one workload, coalesced (stacked-launch) vs fanned
//! execution of the same batched stream, a mixed 3-workload round-robin
//! stream, and the compile-amortization ratio (how many served requests
//! pay back one `coordinator::compile` + plan prepare). Emits
//! `BENCH_serve.json` next to the textual tables; set `BB_BENCH_SMOKE=1`
//! for the seconds-long CI run.
//!
//! Latency here is enqueue→response (queue wait + batched launch), so a
//! full burst's tail requests see queueing delay — the realistic
//! closed-loop number, not the bare launch time.

use blockbuster::exec::ExecBackend;
use blockbuster::serve::{ModelServer, ServerConfig};
use blockbuster::util::bench::{percentile, write_json_report, Table};
use blockbuster::util::json::Json;
use std::time::{Duration, Instant};

fn server_with(max_batch: usize, coalesce: bool, mix: &[&str]) -> ModelServer {
    let mut s = ModelServer::new(ServerConfig {
        backend: ExecBackend::Compiled,
        threads: None,
        max_batch,
        max_wait: Duration::from_secs(3600),
        coalesce,
    });
    for name in mix {
        s.register(name).unwrap();
    }
    s
}

fn main() {
    let smoke = std::env::var("BB_BENCH_SMOKE").is_ok();
    let program = "rmsnorm_ffn_swiglu";
    let n_requests = if smoke { 24 } else { 192 };

    // ---- compile-once cost: register (compile + prepare) one workload
    let t0 = Instant::now();
    drop(server_with(8, false, &[program]));
    let compile_ns = t0.elapsed().as_nanos() as f64;

    // ---- single-workload throughput/latency at batch sizes 1/4/16 ----
    let mut t = Table::new(
        &format!("Serving {program}, {n_requests} requests per row"),
        &["max_batch", "throughput", "mean lat", "p95 lat"],
    );
    let mut rows = Vec::new();
    let mut steady_ns_per_req = f64::NAN;
    for batch in [1usize, 4, 16] {
        let mut server = server_with(batch, false, &[program]);
        // warmup: one full batch through the whole path
        for i in 0..batch as u64 {
            server.submit_synthetic(program, i).unwrap();
        }
        server.drain();

        let t1 = Instant::now();
        for i in 0..n_requests as u64 {
            server.submit_synthetic(program, 10_000 + i).unwrap();
        }
        let responses = server.drain();
        let wall = t1.elapsed();
        assert_eq!(responses.len(), n_requests);

        let lat: Vec<u128> = responses.iter().map(|r| r.queue_ns + r.exec_ns).collect();
        let mean_us = lat.iter().sum::<u128>() as f64 / lat.len() as f64 / 1e3;
        let p95_us = percentile(&lat, 95.0) as f64 / 1e3;
        let rps = n_requests as f64 / wall.as_secs_f64();
        let ns_per_req = wall.as_nanos() as f64 / n_requests as f64;
        if batch == 16 {
            steady_ns_per_req = ns_per_req;
        }
        t.row(vec![
            batch.to_string(),
            format!("{rps:.0} req/s"),
            format!("{mean_us:.1}µs"),
            format!("{p95_us:.1}µs"),
        ]);
        rows.push(Json::obj(vec![
            ("batch", Json::Num(batch as f64)),
            ("throughput_rps", Json::Num(rps)),
            ("mean_latency_us", Json::Num(mean_us)),
            ("p95_latency_us", Json::Num(p95_us)),
        ]));
    }
    t.print();

    // ---- coalesced (stacked launch) vs fanned, same batched stream ----
    // Synthetic requests share weights bit-for-bit, so with coalescing
    // on every full batch rides ONE stacked tape launch; off, each
    // request is its own plan execution fanned across the pool.
    let mut ct = Table::new(
        &format!("Coalescing {program}, max_batch 16, {n_requests} requests"),
        &["mode", "throughput", "kernel launches", "stacked batches"],
    );
    let mut coalesce_rows = Vec::new();
    let mut rps_by_mode = [f64::NAN; 2];
    for (mi, coalesce) in [false, true].into_iter().enumerate() {
        let mut server = server_with(16, coalesce, &[program]);
        for i in 0..16u64 {
            server.submit_synthetic(program, i).unwrap(); // warmup
        }
        server.drain();
        // counter baseline after warmup, so the reported launch ledger
        // covers exactly the timed stream
        let (warm_launches, warm_stacked, warm_coalesced) = {
            let st = &server.stats().per_program[program];
            (st.launches, st.stacked_batches, st.coalesced)
        };
        let t1 = Instant::now();
        for i in 0..n_requests as u64 {
            server.submit_synthetic(program, 30_000 + i).unwrap();
        }
        let responses = server.drain();
        let wall = t1.elapsed();
        assert_eq!(responses.len(), n_requests);
        let st = &server.stats().per_program[program];
        let launches = st.launches - warm_launches;
        let stacked_batches = st.stacked_batches - warm_stacked;
        if coalesce {
            assert!(
                st.coalesced - warm_coalesced > 0,
                "coalescing must engage on {program}"
            );
        }
        let rps = n_requests as f64 / wall.as_secs_f64();
        rps_by_mode[mi] = rps;
        ct.row(vec![
            if coalesce { "coalesced" } else { "fanned" }.to_string(),
            format!("{rps:.0} req/s"),
            launches.to_string(),
            stacked_batches.to_string(),
        ]);
        coalesce_rows.push(Json::obj(vec![
            ("coalesce", Json::Bool(coalesce)),
            ("throughput_rps", Json::Num(rps)),
            ("kernel_launches", Json::Num(launches as f64)),
            ("stacked_batches", Json::Num(stacked_batches as f64)),
        ]));
    }
    ct.print();
    let coalesce_speedup = rps_by_mode[1] / rps_by_mode[0];
    println!("coalesce_speedup: {coalesce_speedup:.2}x (stacked vs fanned throughput)");

    // ---- mixed 3-workload round-robin stream --------------------------
    let mix = ["quickstart", "attention", "rmsnorm_ffn_swiglu"];
    let mut server = server_with(8, false, &mix);
    for (i, name) in mix.iter().enumerate() {
        server.submit_synthetic(name, i as u64).unwrap(); // warmup
    }
    server.drain();
    let t2 = Instant::now();
    for (i, name) in mix.iter().cycle().take(n_requests).enumerate() {
        server.submit_synthetic(name, 20_000 + i as u64).unwrap();
    }
    let responses = server.drain();
    let mixed_wall = t2.elapsed();
    assert_eq!(responses.len(), n_requests);
    let mixed_rps = n_requests as f64 / mixed_wall.as_secs_f64();
    let compiles: u64 = server.stats().per_program.values().map(|s| s.compiles).sum();
    println!(
        "\nmixed {} stream: {mixed_rps:.0} req/s over {n_requests} requests, {compiles} compiles",
        mix.join("+")
    );

    // ---- compile amortization ----------------------------------------
    let amortize = compile_ns / steady_ns_per_req;
    println!(
        "compile+prepare {:.2}ms ≈ {amortize:.0} steady-state requests (batch 16)",
        compile_ns / 1e6
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("serve".into())),
        ("smoke", Json::Bool(smoke)),
        ("program", Json::Str(program.into())),
        ("requests", Json::Num(n_requests as f64)),
        ("compile_ms", Json::Num(compile_ns / 1e6)),
        // requests whose steady-state serving time equals one compile —
        // the compile-once amortization horizon
        ("amortize_requests", Json::Num(amortize)),
        ("batch_rows", Json::Arr(rows)),
        // stacked-launch coalescing vs per-request fan-out on the same
        // batched stream (throughput ratio; >1 means coalescing wins)
        ("coalesce_speedup", Json::Num(coalesce_speedup)),
        ("coalesce_rows", Json::Arr(coalesce_rows)),
        (
            "mixed",
            Json::obj(vec![
                (
                    "programs",
                    Json::Arr(mix.iter().map(|s| Json::Str(s.to_string())).collect()),
                ),
                ("requests", Json::Num(n_requests as f64)),
                ("throughput_rps", Json::Num(mixed_rps)),
                ("compiles", Json::Num(compiles as f64)),
            ]),
        ),
    ]);
    write_json_report("BENCH_serve.json", &report).expect("writing BENCH_serve.json");
    println!("\nwrote BENCH_serve.json");
}
